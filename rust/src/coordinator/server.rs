//! Minimal TCP front-end for interactive serving (std-net, thread-based —
//! tokio is unavailable offline).
//!
//! Line protocol (UTF-8, one request per line):
//!
//! ```text
//! -> GEN <max_new_tokens> <prompt text...>
//! <- OK <ttft_ms> <tpot_ms> <completion text...>
//! <- ERR <message>
//! ```
//!
//! The server owns one engine worker thread per replica; client threads
//! submit requests through a channel and wait on a per-request response
//! channel. This mirrors a serving deployment's (router → engine) split at
//! a small scale; batching still happens inside each engine across
//! concurrent client connections, and with `--replicas N` a
//! [`ClusterFrontend`] load-balances connections across N engine workers
//! by jobs-in-flight (the live-serving analogue of the virtual-clock
//! [`cluster`](super::cluster) driver).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::backend::Backend;
use super::engine::Engine;
use super::precision::PrecisionDirective;
use super::request::Request;

/// A submitted job: prompt plus the channel to answer on.
pub struct Job {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub stop_token: Option<i32>,
    pub respond: mpsc::Sender<JobResult>,
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub tokens: Vec<i32>,
    pub ttft_s: f64,
    pub mean_tpot_s: f64,
}

/// Serve jobs forever on the engine thread: collect whatever is queued,
/// run it as one workload batch, answer, repeat. Returns when the job
/// channel closes.
pub fn engine_worker<B: Backend>(mut engine: Engine<B>, jobs: mpsc::Receiver<Job>) -> Result<()> {
    let (_tx, never) = mpsc::channel();
    engine_worker_controlled(&mut engine, jobs, never)
}

/// [`engine_worker`] plus a cluster-control side channel: before each
/// batch the worker drains `directives` and applies the latest one to its
/// [`PrecisionController`](super::precision::PrecisionController) — the
/// live-serving (wall-clock) analogue of the event-core control loop in
/// [`cluster`](super::cluster). `repro serve --autopilot` feeds this
/// from a monitor thread that runs `Autopilot::control_at` over the
/// frontend's jobs-in-flight counts; unlike the virtual-clock driver it
/// keeps the `due()` interval gate, because wall-clock polling has no
/// event schedule to lean on.
pub fn engine_worker_controlled<B: Backend>(
    engine: &mut Engine<B>,
    jobs: mpsc::Receiver<Job>,
    directives: mpsc::Receiver<PrecisionDirective>,
) -> Result<()> {
    let mut next_id = 0u64;
    loop {
        // block for the first job, then drain whatever arrived meanwhile
        let first = match jobs.recv() {
            Ok(j) => j,
            Err(_) => return Ok(()), // channel closed
        };
        let mut batch = vec![first];
        while let Ok(j) = jobs.try_recv() {
            batch.push(j);
        }
        // apply the latest directive *after* the (possibly long) idle
        // wait, so the batch runs under the autopilot's current rung
        // rather than a pre-idle snapshot; a closed channel just means
        // no autopilot
        while let Ok(d) = directives.try_recv() {
            engine.controller.apply_directive(d);
        }

        let mut requests = Vec::new();
        for job in &batch {
            let mut r = Request::new(next_id, job.prompt.clone(), job.max_new_tokens, 0.0);
            if let Some(s) = job.stop_token {
                r = r.with_stop(s);
            }
            requests.push(r);
            next_id += 1;
        }
        let id_base = next_id - batch.len() as u64;

        // run this batch; harvest per-request outputs from a completion
        // callback shim: the engine drops finished bodies, so we record
        // generations by re-running with collection enabled
        let outputs = run_collecting(engine, requests)?;
        for (i, job) in batch.into_iter().enumerate() {
            let id = id_base + i as u64;
            let out = outputs
                .iter()
                .find(|(rid, _)| *rid == id)
                .map(|(_, o)| o.clone())
                .unwrap_or(JobResult {
                    tokens: vec![],
                    ttft_s: 0.0,
                    mean_tpot_s: 0.0,
                });
            let _ = job.respond.send(out);
        }
    }
}

/// Run a workload and collect per-request outputs (id → result).
pub fn run_collecting<B: Backend>(
    engine: &mut Engine<B>,
    requests: Vec<Request>,
) -> Result<Vec<(u64, JobResult)>> {
    let report = engine.run(requests)?;
    Ok(report
        .completions
        .into_iter()
        .map(|c| {
            (
                c.id,
                JobResult {
                    tokens: c.tokens,
                    ttft_s: c.ttft_s,
                    mean_tpot_s: c.mean_tpot_s,
                },
            )
        })
        .collect())
}

/// One replica's submission handle inside a [`ClusterFrontend`].
struct ReplicaHandle {
    jobs: mpsc::Sender<Job>,
    outstanding: Arc<AtomicUsize>,
    /// Set once a send fails (worker thread exited); the replica is then
    /// skipped forever — without this, a dead replica's outstanding count
    /// drains to 0 and least-in-flight would keep feeding it.
    dead: std::sync::atomic::AtomicBool,
}

/// Live-serving load balancer over N engine workers.
///
/// Dispatches each job to the replica with the fewest jobs in flight
/// (ties go to the lowest index). In-flight counts are maintained by a
/// per-job relay thread that forwards the engine's response to the client
/// and decrements the counter — the engine workers stay completely
/// unaware of the cluster around them.
pub struct ClusterFrontend {
    replicas: Vec<ReplicaHandle>,
}

impl ClusterFrontend {
    /// Wrap one job channel per engine worker.
    pub fn new(senders: Vec<mpsc::Sender<Job>>) -> ClusterFrontend {
        assert!(!senders.is_empty(), "frontend needs at least one replica");
        ClusterFrontend {
            replicas: senders
                .into_iter()
                .map(|jobs| ReplicaHandle {
                    jobs,
                    outstanding: Arc::new(AtomicUsize::new(0)),
                    dead: std::sync::atomic::AtomicBool::new(false),
                })
                .collect(),
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Jobs currently in flight per replica.
    pub fn outstanding(&self) -> Vec<usize> {
        self.replicas
            .iter()
            .map(|r| r.outstanding.load(Ordering::SeqCst))
            .collect()
    }

    /// Dispatch a job to the live replica with the fewest jobs in flight;
    /// fails over to the next-best replica when a worker is gone. Returns
    /// `false` only when every replica is dead.
    pub fn submit(&self, mut job: Job) -> bool {
        loop {
            let mut best: Option<usize> = None;
            for (i, r) in self.replicas.iter().enumerate() {
                if r.dead.load(Ordering::SeqCst) {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => {
                        r.outstanding.load(Ordering::SeqCst)
                            < self.replicas[b].outstanding.load(Ordering::SeqCst)
                    }
                };
                if better {
                    best = Some(i);
                }
            }
            let Some(best) = best else {
                return false; // every replica is dead
            };
            let handle = &self.replicas[best];
            handle.outstanding.fetch_add(1, Ordering::SeqCst);
            let (tx, rx) = mpsc::channel();
            let downstream = std::mem::replace(&mut job.respond, tx);
            match handle.jobs.send(job) {
                Ok(()) => {
                    // only a delivered job gets a relay thread; it forwards
                    // the engine's answer and releases the in-flight slot
                    let counter = Arc::clone(&handle.outstanding);
                    std::thread::spawn(move || {
                        let res = rx.recv();
                        counter.fetch_sub(1, Ordering::SeqCst);
                        if let Ok(r) = res {
                            let _ = downstream.send(r);
                        }
                    });
                    return true;
                }
                Err(mpsc::SendError(mut returned)) => {
                    // worker exited: undo the accounting, write the
                    // replica off, restore the real client sender, retry
                    handle.outstanding.fetch_sub(1, Ordering::SeqCst);
                    handle.dead.store(true, Ordering::SeqCst);
                    returned.respond = downstream;
                    job = returned;
                }
            }
        }
    }
}

/// How client handlers hand jobs to the engine side.
type Submit = Arc<dyn Fn(Job) -> bool + Send + Sync>;

/// Accept loop over a single engine worker: spawns one thread per
/// connection, all feeding the one job channel.
pub fn serve(listener: TcpListener, jobs: mpsc::Sender<Job>, stop_token: Option<i32>) -> Result<()> {
    let jobs = Mutex::new(jobs);
    let submit: Submit = Arc::new(move |job| jobs.lock().unwrap().send(job).is_ok());
    serve_with(listener, submit, stop_token)
}

/// Accept loop over a replica fleet: connections are load-balanced by the
/// [`ClusterFrontend`]. Takes the frontend shared so a monitor thread
/// (e.g. `repro serve --autopilot`) can keep reading its in-flight counts.
pub fn serve_cluster(
    listener: TcpListener,
    frontend: Arc<ClusterFrontend>,
    stop_token: Option<i32>,
) -> Result<()> {
    let submit: Submit = Arc::new(move |job| frontend.submit(job));
    serve_with(listener, submit, stop_token)
}

fn serve_with(listener: TcpListener, submit: Submit, stop_token: Option<i32>) -> Result<()> {
    for stream in listener.incoming() {
        let stream = stream?;
        let submit = Arc::clone(&submit);
        std::thread::spawn(move || {
            let _ = handle_client(stream, submit, stop_token);
        });
    }
    Ok(())
}

fn handle_client(stream: TcpStream, submit: Submit, stop_token: Option<i32>) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // disconnected
        }
        let trimmed = line.trim_end();
        let reply = match parse_gen(trimmed) {
            Some((max_new, prompt)) => {
                let (tx, rx) = mpsc::channel();
                let job = Job {
                    prompt,
                    max_new_tokens: max_new,
                    stop_token,
                    respond: tx,
                };
                submit(job);
                match rx.recv() {
                    Ok(res) => {
                        let text: String = res
                            .tokens
                            .iter()
                            .map(|&t| (t as u8) as char)
                            .collect();
                        format!(
                            "OK {:.1} {:.2} {}\n",
                            res.ttft_s * 1e3,
                            res.mean_tpot_s * 1e3,
                            text
                        )
                    }
                    Err(_) => "ERR engine gone\n".to_string(),
                }
            }
            None => "ERR usage: GEN <max_new> <prompt>\n".to_string(),
        };
        out.write_all(reply.as_bytes())?;
    }
}

/// Parse "GEN <n> <prompt...>"; prompts are byte-level tokens.
pub fn parse_gen(line: &str) -> Option<(usize, Vec<i32>)> {
    let rest = line.strip_prefix("GEN ")?;
    let (n, prompt) = rest.split_once(' ')?;
    let max_new: usize = n.parse().ok()?;
    if prompt.is_empty() || max_new == 0 {
        return None;
    }
    Some((max_new, prompt.bytes().map(|b| b as i32).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> (Job, mpsc::Receiver<JobResult>) {
        let (tx, rx) = mpsc::channel();
        (
            Job {
                prompt: vec![65],
                max_new_tokens: 4,
                stop_token: None,
                respond: tx,
            },
            rx,
        )
    }

    #[test]
    fn frontend_balances_by_jobs_in_flight() {
        let (tx0, rx0) = mpsc::channel();
        let (tx1, rx1) = mpsc::channel();
        let f = ClusterFrontend::new(vec![tx0, tx1]);
        let (j0, r0) = job();
        let (j1, r1) = job();
        assert!(f.submit(j0));
        assert!(f.submit(j1));
        // least-outstanding with tie -> lowest index: one job each
        assert_eq!(f.outstanding(), vec![1, 1]);
        let queued0 = rx0.try_recv().expect("replica 0 got the first job");
        let queued1 = rx1.try_recv().expect("replica 1 got the second job");

        // replica 0 answers: the relay forwards to the client and has
        // already released the in-flight slot by the time we see it
        queued0
            .respond
            .send(JobResult {
                tokens: vec![42],
                ttft_s: 0.001,
                mean_tpot_s: 0.002,
            })
            .unwrap();
        let res = r0
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("relayed response");
        assert_eq!(res.tokens, vec![42]);
        assert_eq!(f.outstanding()[0], 0);

        // replica 1 dies without answering: the client sees a closed
        // channel and the slot is eventually released
        drop(queued1);
        assert!(r1.recv_timeout(std::time::Duration::from_secs(5)).is_err());
        for _ in 0..500 {
            if f.outstanding() == vec![0, 0] {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(f.outstanding(), vec![0, 0]);
    }

    #[test]
    fn frontend_fails_over_past_dead_replicas() {
        let (tx0, rx0) = mpsc::channel::<Job>();
        let (tx1, rx1) = mpsc::channel::<Job>();
        let f = ClusterFrontend::new(vec![tx0, tx1]);
        drop(rx0); // replica 0's worker is gone before the first job
        let (j, r) = job();
        assert!(f.submit(j), "healthy replica 1 must absorb the job");
        let queued = rx1.try_recv().expect("job failed over to replica 1");
        assert_eq!(f.outstanding(), vec![0, 1]);
        // the recovered respond channel still reaches the client
        queued
            .respond
            .send(JobResult {
                tokens: vec![7],
                ttft_s: 0.0,
                mean_tpot_s: 0.0,
            })
            .unwrap();
        let res = r
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("failover must preserve the client channel");
        assert_eq!(res.tokens, vec![7]);
        // with every replica dead, submit reports failure: the send to
        // replica 1 fails, it gets written off, and no candidates remain
        drop(rx1);
        let (j2, _r2) = job();
        assert!(!f.submit(j2));
    }

    #[test]
    fn worker_applies_directives_between_batches() {
        use crate::coordinator::backend::SimBackend;
        use crate::coordinator::engine::{Engine, EngineConfig};
        use crate::coordinator::precision::PrecisionPolicy;
        use crate::gpusim::WeightFormat;
        use crate::model::zoo;

        let spec = zoo::find("llama31-8b").unwrap();
        let backend = SimBackend::new(
            spec,
            WeightFormat::Nested16,
            WeightFormat::Nested8,
            4,
            256,
            256,
        );
        let mut engine = Engine::new(
            backend,
            EngineConfig {
                policy: PrecisionPolicy::Fp16Only,
                physical_kv: false,
                ..Default::default()
            },
        );
        let (jtx, jrx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        // a directive queued before the batch must be live during it —
        // an FP16-only engine then serves FP8, provably via the override
        dtx.send(PrecisionDirective::Fp8).unwrap();
        let (rtx, rrx) = mpsc::channel();
        jtx.send(Job {
            prompt: vec![65; 8],
            max_new_tokens: 4,
            stop_token: None,
            respond: rtx,
        })
        .unwrap();
        drop(jtx); // worker exits after the batch
        engine_worker_controlled(&mut engine, jrx, drx).unwrap();
        let res = rrx.try_recv().expect("batch answered");
        assert_eq!(res.tokens.len(), 4);
        assert_eq!(engine.controller.directive(), PrecisionDirective::Fp8);
        assert!(engine.controller.iters_fp8 > 0, "directive was ignored");
    }

    #[test]
    fn parse_gen_lines() {
        assert_eq!(
            parse_gen("GEN 8 C:ab="),
            Some((8, vec![67, 58, 97, 98, 61]))
        );
        assert!(parse_gen("GEN x yz").is_none());
        assert!(parse_gen("GEN 8 ").is_none());
        assert!(parse_gen("NOPE 8 x").is_none());
        assert!(parse_gen("GEN 0 x").is_none());
    }
}

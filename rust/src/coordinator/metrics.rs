//! Serving metrics: TTFT / TPOT digests, SLO-violation accounting, the
//! per-second violation timeline used by Figure 1b, and cluster-level
//! aggregation ([`Metrics::merge`], goodput) for multi-replica runs.
//!
//! Scalar counters live behind one registration point,
//! [`Metrics::scalar_registry`]: each counter is declared there once
//! with its merge rule (sum / max / min), and both cross-replica
//! aggregation and the `--json` counter dump derive from that single
//! declaration — the old hand-written field-by-field merge could
//! silently disagree with the dump; the registry cannot.

use std::collections::BTreeMap;

use crate::kvcache::KvCacheStats;
use crate::telemetry::registry::{MergeRule, Registry};
use crate::util::stats::{Digest, Summary};

use super::precision::SloConfig;
use super::request::Request;

/// Aggregated metrics for one serving run.
#[derive(Default)]
pub struct Metrics {
    pub ttft: Digest,
    /// Per-token inter-arrival latencies (the TPOT samples).
    pub tpot: Digest,
    /// Per-request mean TPOT.
    pub tpot_per_request: Digest,
    /// (second index, worst TPOT in that second) timeline.
    pub tpot_by_second: Vec<(u64, f64)>,
    pub completed: usize,
    pub total_prompt_tokens: usize,
    pub total_output_tokens: usize,
    /// Per-request `(ttft, mean_tpot)` pairs — the goodput accounting
    /// needs both latencies of the *same* request (the digests lose that
    /// pairing). `mean_tpot` is 0 for single-token generations.
    pub request_latencies: Vec<(f64, f64)>,
    /// Engine-clock span of the run (first arrival .. last completion).
    pub t_start: f64,
    pub t_end: f64,
    // ---- paged-KV counters (mirrored from the engine's cache) ----
    /// Blocks demoted to FP8 over the run.
    pub kv_demoted_blocks: usize,
    /// Sequence preemptions to the host tier.
    pub kv_offload_events: usize,
    /// Host → device resume fetches.
    pub kv_fetch_events: usize,
    /// Virtual-clock seconds spent on host transfers.
    pub kv_transfer_seconds: f64,
    /// Peak device block utilization in [0, 1] (max over merge).
    pub peak_kv_utilization: f64,
    /// Peak concurrently admitted sequences (summed over merge: cluster
    /// aggregate = total concurrent capacity actually reached).
    pub peak_live_seqs: usize,
    // ---- autopilot counters (mirrored from the cluster control loop) ----
    /// Virtual-clock seconds spent under each precision directive,
    /// indexed by `PrecisionDirective::rung()`: `[fp16, mixed, fp8]`.
    /// Summed over merge: the cluster aggregate is total replica-seconds
    /// per mode.
    pub mode_dwell_s: [f64; 3],
    /// Directive switches (one ladder rung each). Summed over merge.
    pub mode_switches: usize,
    // ---- shard-layer counters (mirrored from the cluster's resharder) ----
    /// Completed reshards (TP-degree changes). Summed over merge.
    pub reshards: usize,
    /// Virtual-clock seconds spent inside repartition windows (the
    /// weight-move part of drain → repartition → resume). Summed over
    /// merge: cluster aggregate is total replica-seconds repartitioning.
    pub reshard_repartition_s: f64,
    // ---- attention-traffic counters (mirrored from StepRun) ----
    /// Cumulative bytes a dense-gather attention path would have copied
    /// (the pre-PR 5 `gather_seq`/`gather_batch` traffic). Summed over
    /// merge.
    pub attn_dense_bytes: usize,
    /// Cumulative KV bytes the block-native attention actually touched,
    /// at stored precision (FP8 blocks count roughly half). Summed over
    /// merge.
    pub attn_touched_bytes: usize,
    // ---- host-piggyback counters (mirrored from StepRun / the engine) ----
    /// Decode iterations that carried at least one host-piggybacked
    /// attention lane. Summed over merge.
    pub host_piggybacked_steps: usize,
    /// Cumulative host-piggybacked lanes served (lanes × iterations).
    /// Summed over merge.
    pub host_lanes_served: usize,
    /// Virtual-clock seconds the host tier spent serving piggybacked
    /// attention (the sim backend's host cost law). Summed over merge.
    pub host_attn_seconds: f64,
    /// PCIe transfer seconds avoided by sequences that finished on the
    /// host tier (their resume fetch never happened; blocks were
    /// discarded in place). Summed over merge.
    pub host_transfer_seconds_avoided: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            t_start: f64::INFINITY,
            ..Default::default()
        }
    }

    /// Record one finished request.
    pub fn record_request(&mut self, r: &Request) {
        debug_assert!(r.is_finished());
        self.completed += 1;
        self.total_prompt_tokens += r.prompt.len();
        self.total_output_tokens += r.generated.len();
        self.t_start = self.t_start.min(r.arrival);
        if let Some(t) = r.finished_at {
            self.t_end = self.t_end.max(t);
        }
        if let Some(ft) = r.first_token_at {
            self.ttft.add(ft - r.arrival);
            let mut mean_tpot = 0.0;
            if r.generated.len() > 1 {
                if let Some(done) = r.finished_at {
                    mean_tpot = (done - ft) / (r.generated.len() - 1) as f64;
                    self.tpot_per_request.add(mean_tpot);
                }
            }
            self.request_latencies.push((ft - r.arrival, mean_tpot));
        }
    }

    /// Record one decode iteration at engine time `now`. `gaps` holds the
    /// per-sequence inter-token times (now - that sequence's previous
    /// token) — the true TPOT, which includes time the sequence spent
    /// waiting while other iterations (e.g. prefill chunks) ran.
    pub fn record_decode_iteration(&mut self, now: f64, gaps: &[f64]) {
        let mut worst = 0.0f64;
        for &g in gaps {
            self.tpot.add(g);
            worst = worst.max(g);
        }
        let sec = now as u64;
        match self.tpot_by_second.last_mut() {
            Some((s, w)) if *s == sec => *w = w.max(worst),
            _ => self.tpot_by_second.push((sec, worst)),
        }
    }

    /// Mirror the engine cache's cumulative counters (called once per
    /// iteration; the stats are monotone, so overwriting is exact).
    pub fn observe_kv(&mut self, s: &KvCacheStats) {
        self.kv_demoted_blocks = s.demoted_blocks;
        self.kv_offload_events = s.offload_events;
        self.kv_fetch_events = s.fetch_events;
        self.kv_transfer_seconds = s.transfer_seconds;
        self.peak_kv_utilization = self.peak_kv_utilization.max(s.peak_utilization);
        self.peak_live_seqs = self.peak_live_seqs.max(s.peak_live_seqs);
    }

    /// Seconds of the run whose worst TPOT violated the SLO (Fig 1b's
    /// "seconds of SLO violation").
    pub fn slo_violation_seconds(&self, slo: &SloConfig) -> usize {
        self.tpot_by_second
            .iter()
            .filter(|(_, worst)| *worst > slo.tpot_target)
            .count()
    }

    /// Output-token throughput over the run, tokens/second.
    pub fn throughput_tok_s(&self) -> f64 {
        let span = self.t_end - self.t_start;
        if span <= 0.0 {
            return 0.0;
        }
        self.total_output_tokens as f64 / span
    }

    pub fn ttft_summary(&mut self) -> Summary {
        self.ttft.summary()
    }

    pub fn tpot_summary(&mut self) -> Summary {
        self.tpot.summary()
    }

    /// Completed requests that met both SLO targets (TTFT and mean TPOT).
    pub fn slo_attained(&self, slo: &SloConfig) -> usize {
        self.request_latencies
            .iter()
            .filter(|(ttft, tpot)| *ttft <= slo.ttft_target && *tpot <= slo.tpot_target)
            .count()
    }

    /// Goodput: SLO-attaining completed requests per second over the run
    /// span — the cluster-level success metric (throughput alone rewards
    /// finishing requests late).
    pub fn goodput_req_s(&self, slo: &SloConfig) -> f64 {
        let span = self.t_end - self.t_start;
        if span <= 0.0 || !span.is_finite() {
            return 0.0;
        }
        self.slo_attained(slo) as f64 / span
    }

    /// Accumulate one step's attention-traffic counters (from
    /// `StepRun`): dense-equivalent gathered bytes vs. block bytes
    /// actually touched.
    pub fn observe_attn(&mut self, dense_bytes: usize, touched_bytes: usize) {
        self.attn_dense_bytes += dense_bytes;
        self.attn_touched_bytes += touched_bytes;
    }

    /// Fraction of the dense gather's KV traffic the block-native
    /// attention avoided over the run (0 when nothing was recorded).
    pub fn attn_gather_savings(&self) -> f64 {
        if self.attn_dense_bytes == 0 {
            return 0.0;
        }
        1.0 - self.attn_touched_bytes as f64 / self.attn_dense_bytes as f64
    }

    /// Accumulate one mixed-tier decode iteration's host-lane counters
    /// (from `StepRun`). Called only when the iteration actually carried
    /// host lanes.
    pub fn observe_host_decode(&mut self, host_lanes: usize, host_attn_s: f64) {
        self.host_piggybacked_steps += 1;
        self.host_lanes_served += host_lanes;
        self.host_attn_seconds += host_attn_s;
    }

    /// Credit the resume transfer a host-finishing sequence never paid.
    pub fn credit_avoided_transfer(&mut self, seconds: f64) {
        self.host_transfer_seconds_avoided += seconds;
    }

    /// Mirror the autopilot's per-replica dwell/switch accounting (see
    /// `coordinator::autopilot::ModeStats`; passed as plain values to
    /// keep this module's dependencies one-directional).
    pub fn observe_modes(&mut self, dwell_s: [f64; 3], switches: usize) {
        self.mode_dwell_s = dwell_s;
        self.mode_switches = switches;
    }

    /// Mirror the cluster resharder's cumulative counters (monotone, so
    /// overwriting is exact — same convention as [`Metrics::observe_kv`]).
    pub fn observe_reshards(&mut self, reshards: usize, repartition_s: f64) {
        self.reshards = reshards;
        self.reshard_repartition_s = repartition_s;
    }

    /// Declare every scalar counter with its cross-replica merge rule.
    /// This is the single source of truth: [`Metrics::merge`] aggregates
    /// by merging two of these registries, and the `--json` counter dump
    /// serializes the same one — neither can drift from the other.
    ///
    /// The `t_start`/`t_end` pair rides along (Min / Max rules), so the
    /// run span merges through the same mechanism as the counters.
    pub fn scalar_registry(&self) -> Registry {
        use MergeRule::{Max, Min, Sum};
        let mut r = Registry::new();
        r.set_int("requests.completed", Sum, self.completed as u64);
        r.set_int("tokens.prompt", Sum, self.total_prompt_tokens as u64);
        r.set_int("tokens.output", Sum, self.total_output_tokens as u64);
        r.set_float("run.t_start_s", Min, self.t_start);
        r.set_float("run.t_end_s", Max, self.t_end);
        r.set_int("kv.demoted_blocks", Sum, self.kv_demoted_blocks as u64);
        r.set_int("kv.offload_events", Sum, self.kv_offload_events as u64);
        r.set_int("kv.fetch_events", Sum, self.kv_fetch_events as u64);
        r.set_float("kv.transfer_s", Sum, self.kv_transfer_seconds);
        r.set_float("kv.peak_utilization", Max, self.peak_kv_utilization);
        // cluster peak = sum of replica peaks (total concurrency reached)
        r.set_int("kv.peak_live_seqs", Sum, self.peak_live_seqs as u64);
        r.set_float("mode.dwell_fp16_s", Sum, self.mode_dwell_s[0]);
        r.set_float("mode.dwell_mixed_s", Sum, self.mode_dwell_s[1]);
        r.set_float("mode.dwell_fp8_s", Sum, self.mode_dwell_s[2]);
        r.set_int("mode.switches", Sum, self.mode_switches as u64);
        r.set_int("shard.reshards", Sum, self.reshards as u64);
        r.set_float("shard.repartition_s", Sum, self.reshard_repartition_s);
        r.set_int("attn.dense_bytes", Sum, self.attn_dense_bytes as u64);
        r.set_int("attn.touched_bytes", Sum, self.attn_touched_bytes as u64);
        r.set_int("host.piggybacked_steps", Sum, self.host_piggybacked_steps as u64);
        r.set_int("host.lanes_served", Sum, self.host_lanes_served as u64);
        r.set_float("host.attn_s", Sum, self.host_attn_seconds);
        r.set_float(
            "host.transfer_s_avoided",
            Sum,
            self.host_transfer_seconds_avoided,
        );
        r
    }

    /// Read every scalar back from a merged registry (inverse of
    /// [`Metrics::scalar_registry`]).
    fn apply_scalars(&mut self, r: &Registry) {
        self.completed = r.int("requests.completed") as usize;
        self.total_prompt_tokens = r.int("tokens.prompt") as usize;
        self.total_output_tokens = r.int("tokens.output") as usize;
        self.t_start = r.float("run.t_start_s");
        self.t_end = r.float("run.t_end_s");
        self.kv_demoted_blocks = r.int("kv.demoted_blocks") as usize;
        self.kv_offload_events = r.int("kv.offload_events") as usize;
        self.kv_fetch_events = r.int("kv.fetch_events") as usize;
        self.kv_transfer_seconds = r.float("kv.transfer_s");
        self.peak_kv_utilization = r.float("kv.peak_utilization");
        self.peak_live_seqs = r.int("kv.peak_live_seqs") as usize;
        self.mode_dwell_s = [
            r.float("mode.dwell_fp16_s"),
            r.float("mode.dwell_mixed_s"),
            r.float("mode.dwell_fp8_s"),
        ];
        self.mode_switches = r.int("mode.switches") as usize;
        self.reshards = r.int("shard.reshards") as usize;
        self.reshard_repartition_s = r.float("shard.repartition_s");
        self.attn_dense_bytes = r.int("attn.dense_bytes") as usize;
        self.attn_touched_bytes = r.int("attn.touched_bytes") as usize;
        self.host_piggybacked_steps = r.int("host.piggybacked_steps") as usize;
        self.host_lanes_served = r.int("host.lanes_served") as usize;
        self.host_attn_seconds = r.float("host.attn_s");
        self.host_transfer_seconds_avoided = r.float("host.transfer_s_avoided");
    }

    /// Fold another replica's metrics into this one (cluster aggregation).
    ///
    /// Scalars merge through [`Metrics::scalar_registry`] — each
    /// counter's rule (sum / max / min) is declared exactly once there.
    /// Digests concatenate — merged percentile summaries
    /// ([`Metrics::ttft_summary`] / [`Metrics::tpot_summary`]) are
    /// therefore recomputed from the **pooled samples**, never from
    /// averaging per-replica summaries (averaging p99s of skewed replicas
    /// understates the tail; see `merge_pools_samples_for_percentiles`).
    /// The per-second worst-TPOT timelines merge by second taking the
    /// max, so `slo_violation_seconds` counts a second as violated when
    /// *any* replica violated during it.
    pub fn merge(&mut self, other: &Metrics) {
        self.ttft.extend_from(&other.ttft);
        self.tpot.extend_from(&other.tpot);
        self.tpot_per_request.extend_from(&other.tpot_per_request);
        self.request_latencies
            .extend_from_slice(&other.request_latencies);
        let mut scalars = self.scalar_registry();
        scalars.merge(&other.scalar_registry());
        self.apply_scalars(&scalars);
        let mut by_sec: BTreeMap<u64, f64> = self.tpot_by_second.iter().cloned().collect();
        for &(sec, worst) in &other.tpot_by_second {
            let w = by_sec.entry(sec).or_insert(0.0);
            if worst > *w {
                *w = worst;
            }
        }
        self.tpot_by_second = by_sec.into_iter().collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{FinishReason, Request, RequestState};

    fn finished_request(arrival: f64, first: f64, done: f64, n_out: usize) -> Request {
        let mut r = Request::new(1, vec![1, 2], 64, arrival);
        r.state = RequestState::Finished;
        r.prefilled = 2;
        r.generated = vec![0; n_out];
        r.first_token_at = Some(first);
        r.finished_at = Some(done);
        r.finish_reason = Some(FinishReason::Length);
        r
    }

    #[test]
    fn ttft_and_tpot_math() {
        let mut m = Metrics::new();
        // arrival 1.0, first token 1.2, done 2.2, 11 tokens
        m.record_request(&finished_request(1.0, 1.2, 2.2, 11));
        assert_eq!(m.completed, 1);
        let s = m.ttft_summary();
        assert!((s.p50 - 0.2).abs() < 1e-9);
        let tp = m.tpot_per_request.percentile(50.0);
        assert!((tp - 0.1).abs() < 1e-9, "{tp}");
        assert!((m.throughput_tok_s() - 11.0 / 1.2).abs() < 1e-9);
    }

    #[test]
    fn merge_aggregates_replicas() {
        let slo = SloConfig::default();
        let mut a = Metrics::new();
        a.record_request(&finished_request(0.0, 0.1, 1.1, 11)); // meets both SLOs? ttft 0.1<=0.2, tpot 0.1>0.0333 -> no
        a.record_decode_iteration(0.5, &[0.010]);
        let mut b = Metrics::new();
        b.record_request(&finished_request(2.0, 2.1, 2.4, 11)); // ttft 0.1, tpot 0.03 -> yes
        b.record_decode_iteration(0.7, &[0.050]); // violates second 0 too
        b.record_decode_iteration(3.0, &[0.020]);

        a.observe_kv(&crate::kvcache::KvCacheStats {
            demoted_blocks: 3,
            offload_events: 1,
            peak_live_seqs: 2,
            peak_utilization: 0.9,
            ..Default::default()
        });
        b.observe_kv(&crate::kvcache::KvCacheStats {
            demoted_blocks: 1,
            fetch_events: 1,
            transfer_seconds: 0.002,
            peak_live_seqs: 3,
            peak_utilization: 0.5,
            ..Default::default()
        });

        let mut m = Metrics::new();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.kv_demoted_blocks, 4);
        assert_eq!(m.kv_offload_events, 1);
        assert_eq!(m.kv_fetch_events, 1);
        assert!((m.kv_transfer_seconds - 0.002).abs() < 1e-15);
        assert_eq!(m.peak_live_seqs, 5, "cluster peak = sum of replica peaks");
        assert!((m.peak_kv_utilization - 0.9).abs() < 1e-15);
        assert_eq!(m.completed, 2);
        assert_eq!(m.ttft.len(), 2);
        assert_eq!(m.total_output_tokens, 22);
        assert_eq!(m.t_start, 0.0);
        assert_eq!(m.t_end, 2.4);
        // second 0 appears once, with the max (violating) value
        assert_eq!(m.tpot_by_second.len(), 2);
        assert_eq!(m.slo_violation_seconds(&slo), 1);
        assert_eq!(m.slo_attained(&slo), 1);
        assert!((m.goodput_req_s(&slo) - 1.0 / 2.4).abs() < 1e-12);
    }

    #[test]
    fn merge_pools_samples_for_percentiles() {
        // Two deliberately skewed replicas. Replica A: nine fast requests
        // (TTFT 10 ms, decode gaps 10 ms). Replica B: one slow request
        // (TTFT 400 ms, decode gaps 100 ms). The pooled p50 sits at the
        // fast mode; averaging the two per-replica summaries instead
        // would report the midpoint — and the pooled p99 sits in the slow
        // tail, which summary-averaging would *understate*. This test
        // pins the pooled semantics and fails for either skew direction.
        let mut a = Metrics::new();
        for i in 0..9 {
            let t0 = i as f64;
            a.record_request(&finished_request(t0, t0 + 0.010, t0 + 0.110, 11));
            a.record_decode_iteration(t0 + 0.5, &[0.010; 10]);
        }
        let mut b = Metrics::new();
        b.record_request(&finished_request(0.0, 0.400, 1.400, 11));
        b.record_decode_iteration(0.9, &[0.100; 10]);

        // what averaging the per-replica summaries would claim:
        // 0.205 s and 0.055 s respectively
        let avg_ttft_p50 = (a.ttft_summary().p50 + b.ttft_summary().p50) / 2.0;
        let avg_tpot_p99 = (a.tpot_summary().p99 + b.tpot_summary().p99) / 2.0;

        let mut m = Metrics::new();
        m.merge(&a);
        m.merge(&b);
        let ttft = m.ttft_summary();
        let tpot = m.tpot_summary();
        assert_eq!(ttft.count, 10, "pooled sample count");
        assert_eq!(tpot.count, 100);
        // p50 of 9x10ms + 1x400ms is 10 ms, nowhere near the 205 ms average
        assert!((ttft.p50 - 0.010).abs() < 1e-9, "pooled p50 {}", ttft.p50);
        assert!(
            (ttft.p50 - avg_ttft_p50).abs() > 0.1,
            "pooled p50 must not look like a summary average"
        );
        // p99 of 90x10ms + 10x100ms lands in the slow tail (>= 90 ms);
        // summary-averaging would halve it
        assert!(tpot.p99 > 0.090, "pooled p99 {} lost the tail", tpot.p99);
        assert!(
            tpot.p99 > avg_tpot_p99 + 0.030,
            "pooled p99 {} vs averaged {avg_tpot_p99}: tail understated",
            tpot.p99
        );
    }

    #[test]
    fn mode_counters_merge_by_sum() {
        let mut a = Metrics::new();
        a.observe_modes([10.0, 4.0, 1.0], 3);
        a.observe_reshards(2, 0.25);
        let mut b = Metrics::new();
        b.observe_modes([2.0, 0.5, 7.5], 5);
        b.observe_reshards(1, 0.10);
        let mut m = Metrics::new();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.mode_dwell_s, [12.0, 4.5, 8.5]);
        assert_eq!(m.mode_switches, 8);
        assert_eq!(m.reshards, 3);
        assert!((m.reshard_repartition_s - 0.35).abs() < 1e-12);
    }

    #[test]
    fn attn_counters_accumulate_and_merge() {
        let mut a = Metrics::new();
        a.observe_attn(1000, 250);
        a.observe_attn(1000, 150);
        assert_eq!(a.attn_dense_bytes, 2000);
        assert_eq!(a.attn_touched_bytes, 400);
        assert!((a.attn_gather_savings() - 0.8).abs() < 1e-12);
        let mut b = Metrics::new();
        b.observe_attn(2000, 2000); // a replica with no headroom
        let mut m = Metrics::new();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.attn_dense_bytes, 4000);
        assert_eq!(m.attn_touched_bytes, 2400);
        assert!((m.attn_gather_savings() - 0.4).abs() < 1e-12);
        assert_eq!(Metrics::new().attn_gather_savings(), 0.0);
    }

    #[test]
    fn host_piggyback_counters_merge_by_sum() {
        let mut a = Metrics::new();
        a.observe_host_decode(2, 0.001);
        a.observe_host_decode(1, 0.0005);
        a.credit_avoided_transfer(0.01);
        let mut b = Metrics::new();
        b.observe_host_decode(4, 0.002);
        let mut m = Metrics::new();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.host_piggybacked_steps, 3);
        assert_eq!(m.host_lanes_served, 7);
        assert!((m.host_attn_seconds - 0.0035).abs() < 1e-12);
        assert!((m.host_transfer_seconds_avoided - 0.01).abs() < 1e-12);
    }

    #[test]
    fn violation_seconds() {
        let mut m = Metrics::new();
        let slo = SloConfig::default();
        m.record_decode_iteration(0.5, &[0.010; 4]); // fine
        m.record_decode_iteration(1.2, &[0.050; 4]); // violation in second 1
        m.record_decode_iteration(1.8, &[0.020; 4]); // same second, fine
        m.record_decode_iteration(2.1, &[0.040; 4]); // violation in second 2
        assert_eq!(m.slo_violation_seconds(&slo), 2);
        assert_eq!(m.tpot.len(), 16);
    }
}

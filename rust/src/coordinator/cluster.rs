//! Cluster-scale serving: N independent engine replicas behind one router,
//! driven by the discrete-event core.
//!
//! Each replica is a full [`Engine`] — its own `KvCacheManager`,
//! `Scheduler`, and `PrecisionController` — exactly as if it were a
//! single-GPU deployment. The [`ClusterRouter`] adds the two cluster-level
//! mechanisms the paper's SLO story needs at scale:
//!
//! 1. **Dispatch** — every arriving request is routed once, by a pluggable
//!    [`RoutingPolicy`], using only cheap per-replica load snapshots
//!    (free KV blocks, queue depth, TPOT EWMA). No request migration.
//! 2. **Cluster-level precision control** — either the PR-1 *staged
//!    escalation* (queue pressure demotes replicas to FP8 one at a time,
//!    highest index first, via [`PrecisionController::set_forced`]) or,
//!    when [`ClusterConfig::autopilot`] is set, the closed-loop
//!    [`Autopilot`](super::autopilot): sliding-window SLO tracking,
//!    per-replica FP16 → Mixed → FP8 hysteresis ladders, and an
//!    EWMA-slope surge predictor that pre-escalates before the queue
//!    backs up. Either way a surge costs FP16 quality only on the
//!    replicas actually needed to absorb it.
//!
//! Scheduling is discrete-event (see [`event_core`](super::event_core)
//! and `docs/ARCHITECTURE.md` §"The Event Core"): arrival injection, the
//! control loop, the predictor's bucket clock, and every replica engine
//! are [`Component`]s drained from one deterministic min-heap, ties
//! broken by component id. Idle replicas are parked — they cost zero
//! work between their events (the run reports
//! [`EventStats::idle_replica_events`], which must stay 0), so a
//! scenario can drive hundreds of replicas over multi-hour traces. The
//! retired lockstep loop survives as the `drive_lockstep` oracle behind
//! [`ClusterRouter::run_lockstep`], and the equivalence suite pins the
//! two drivers bit-for-bit.

use std::collections::VecDeque;

use anyhow::{anyhow, Result};

use crate::model::zoo::ModelSpec;
use crate::shard::{ReshardCost, ReshardState, Resharder, ShardPlan};
use crate::telemetry::trace::{self, Kind};

use super::autopilot::{Autopilot, AutopilotConfig, ModeStats};
use super::backend::Backend;
use super::engine::{CompletedRequest, Engine, EngineConfig};
use super::event_core::{self, Component, ComponentId, QueueStats, Waker};
use super::metrics::Metrics;
use super::precision::{LayerSchedule, Precision, PrecisionController, PrecisionDirective};
use super::request::Request;
use super::router::{ReplicaSnapshot, Router, RoutingPolicy};

/// Staged FP8-escalation thresholds (virtual-clock seconds).
#[derive(Clone, Copy, Debug)]
pub struct SurgeConfig {
    /// Cluster-wide queued requests *per replica* that warrant demoting
    /// one more replica: stage k engages at `k * queue_per_stage`.
    pub queue_per_stage: f64,
    /// Release stage k once pressure falls to `release_frac` of its
    /// engagement threshold (hysteresis, like the engine controller's
    /// high/low water marks).
    pub release_frac: f64,
    /// Minimum seconds between stage changes (dwell against flapping).
    pub min_dwell_s: f64,
    /// Spacing of staged-escalation control ticks on the virtual clock.
    /// The event core schedules the control loop as its own component at
    /// exactly this cadence (matching the autopilot's
    /// `control_interval_s` default), instead of piggybacking on
    /// whichever replica event happens to land nearby.
    pub control_interval_s: f64,
}

impl Default for SurgeConfig {
    fn default() -> Self {
        SurgeConfig {
            queue_per_stage: 3.0,
            release_frac: 0.5,
            min_dwell_s: 1.0,
            control_interval_s: 0.25,
        }
    }
}

impl SurgeConfig {
    /// Thresholds no workload can reach — the legacy staged escalation
    /// never engages. Used by the static bench arms (a "static FP16"
    /// baseline must not be quietly demoted mid-run) and implied whenever
    /// [`ClusterConfig::autopilot`] is set (the autopilot owns forcing).
    /// The control-loop component stays entirely unscheduled in this
    /// state — disabled control costs zero events, not cheap events.
    pub fn disabled() -> SurgeConfig {
        SurgeConfig {
            queue_per_stage: f64::INFINITY,
            release_frac: 0.5,
            min_dwell_s: 0.0,
            control_interval_s: 0.25,
        }
    }
}

/// Cluster construction parameters.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Dispatch policy for arriving requests.
    pub policy: RoutingPolicy,
    /// Per-replica engine configuration (each replica gets a copy).
    pub engine: EngineConfig,
    /// Staged-escalation thresholds (the PR-1 reactive fallback; ignored
    /// when `autopilot` is set).
    pub surge: SurgeConfig,
    /// Closed-loop SLO autopilot. When set it **replaces** the staged
    /// escalation: sliding-window SLO tracking, per-replica
    /// FP16 → Mixed → FP8 hysteresis ladders, and the surge predictor
    /// drive every [`PrecisionController::apply_directive`] call. Its
    /// `max_tp` also arms the second (parallelism) ladder, whose targets
    /// the cluster's [`Resharder`] executes as drain → repartition →
    /// resume windows.
    pub autopilot: Option<AutopilotConfig>,
    /// Repartition-window cost law for TP changes.
    pub reshard: ReshardCost,
    /// Keep the full [`ClusterReport::control_ticks`] vector. Golden and
    /// regression suites need every tick; multi-hour `--scale` runs set
    /// this `false` and get the bounded count + first/last 16 instead
    /// (a 21600 s trace at 0.25 s cadence is ~86k f64s per run kept
    /// alive for nothing).
    pub record_control_ticks: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            policy: RoutingPolicy::SloHeadroom,
            engine: EngineConfig::default(),
            surge: SurgeConfig::default(),
            autopilot: None,
            reshard: ReshardCost::default(),
            record_control_ticks: true,
        }
    }
}

/// One replica's share of a cluster run.
pub struct ReplicaReport {
    pub metrics: Metrics,
    pub controller: PrecisionController,
    /// (time, is_fp8) change points of the replica's served precision.
    pub mode_timeline: Vec<(f64, bool)>,
    pub iterations: usize,
    /// Requests the router dispatched to this replica.
    pub routed: usize,
    /// Autopilot directive dwell/switch accounting (zeros when the
    /// autopilot is disabled; also mirrored into `metrics`).
    pub mode_stats: ModeStats,
    /// (time, new directive) switch points of the autopilot's
    /// per-replica ladder (empty when disabled; initial state is FP16).
    pub directive_timeline: Vec<(f64, PrecisionDirective)>,
    /// Device blocks free at the end of the run — with the workload fully
    /// drained this must equal `total_kv_blocks` (the golden-trace suite
    /// asserts it: leaks fail loudly).
    pub final_free_kv_blocks: usize,
    /// Host-tier blocks still resident at the end (must be 0 drained).
    pub final_host_kv_blocks: usize,
    pub total_kv_blocks: usize,
    /// Tensor-parallel degree the replica finished the run at.
    pub final_tp_degree: usize,
}

/// Per-event accounting of one cluster run: how many times each
/// component class was dispatched. Surfaced in the `--scale` bench JSON;
/// the equivalence suite asserts the dispatch counters match across the
/// heap driver and the lockstep oracle.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EventStats {
    /// Arrival-injector dispatches (one per routed request).
    pub arrival_events: usize,
    /// Control-loop dispatches (staged escalation or autopilot).
    pub control_events: usize,
    /// Predictor bucket-clock dispatches (autopilot runs only).
    pub predictor_events: usize,
    /// Replica dispatches that ran or attempted an engine step.
    pub replica_step_events: usize,
    /// Replica dispatches whose engine step reported `ran == false`
    /// (queued-but-unadmittable work; the replica re-arms at the next
    /// arrival instead of spinning).
    pub replica_blocked_wakes: usize,
    /// Events dispatched to a replica with **no active work**. The event
    /// core's contract is that this stays zero: idle replicas are
    /// parked, not polled — the `--scale` arm asserts it at 100+
    /// replicas.
    pub idle_replica_events: usize,
    /// Resharder dispatches (repartition-window deadlines). Zero on any
    /// run that never moves the parallelism knob — the resharder parks.
    pub reshard_events: usize,
    /// Driver-level queue counters (scheduled / popped / stale).
    pub queue: QueueStats,
}

impl EventStats {
    /// Declare the dispatch counters in a telemetry registry under
    /// `prefix` (all summed across runs).
    pub fn register_into(&self, r: &mut crate::telemetry::Registry, prefix: &str) {
        use crate::telemetry::registry::MergeRule::Sum;
        r.set_int(&format!("{prefix}.arrival"), Sum, self.arrival_events as u64);
        r.set_int(&format!("{prefix}.control"), Sum, self.control_events as u64);
        r.set_int(&format!("{prefix}.predictor"), Sum, self.predictor_events as u64);
        r.set_int(&format!("{prefix}.replica_step"), Sum, self.replica_step_events as u64);
        r.set_int(&format!("{prefix}.replica_blocked"), Sum, self.replica_blocked_wakes as u64);
        r.set_int(&format!("{prefix}.idle_replica"), Sum, self.idle_replica_events as u64);
        r.set_int(&format!("{prefix}.reshard"), Sum, self.reshard_events as u64);
        r.set_int(&format!("{prefix}.queue_scheduled"), Sum, self.queue.scheduled);
        r.set_int(&format!("{prefix}.queue_popped"), Sum, self.queue.popped);
        r.set_int(&format!("{prefix}.queue_stale"), Sum, self.queue.stale);
    }
}

/// Outcome of a full cluster run.
pub struct ClusterReport {
    pub replicas: Vec<ReplicaReport>,
    /// All replicas' metrics merged — cluster-level TTFT/TPOT/goodput.
    pub aggregate: Metrics,
    pub completions: Vec<CompletedRequest>,
    /// (time, replicas pinned to FP8) change points — staged escalation
    /// stages, or the count of FP8 directives under the autopilot.
    pub demotion_timeline: Vec<(f64, usize)>,
    /// (time, ladder severity) change points of the autopilot's cluster
    /// escalation ladder (empty when disabled).
    pub ladder_timeline: Vec<(f64, usize)>,
    /// Severity increases driven by the surge predictor before measured
    /// pressure crossed the threshold.
    pub pre_escalations: usize,
    /// Virtual times of every control tick that fired. The event core
    /// schedules these exactly `control_interval_s` apart from the first
    /// arrival onward — including across arrival droughts where no
    /// replica event lands on the same instant (the control-tick-skew
    /// regression suite asserts the cadence). Empty when
    /// [`ClusterConfig::record_control_ticks`] is off — use the bounded
    /// `control_tick_count` / head / tail fields instead.
    pub control_ticks: Vec<f64>,
    /// Control ticks fired, counted regardless of recording mode.
    pub control_tick_count: usize,
    /// First ≤16 control-tick times (always populated).
    pub control_ticks_head: Vec<f64>,
    /// Last ≤16 control-tick times (always populated).
    pub control_ticks_tail: Vec<f64>,
    /// `(time, replica, new tp)` per completed reshard, in completion
    /// order (the resharder's own timeline).
    pub reshard_timeline: Vec<(f64, usize, usize)>,
    /// Per-event accounting for the run.
    pub events: EventStats,
}

impl ClusterReport {
    /// Fraction of all engine iterations served at FP16, cluster-wide.
    pub fn fp16_fraction(&self) -> f64 {
        let (mut f16, mut f8) = (0usize, 0usize);
        for r in &self.replicas {
            f16 += r.controller.iters_fp16;
            f8 += r.controller.iters_fp8;
        }
        if f16 + f8 == 0 {
            1.0
        } else {
            f16 as f64 / (f16 + f8) as f64
        }
    }
}

// ---- component ids --------------------------------------------------
// The id is the index in the component slice (the event core's tie-break
// law), so the ordering below is part of the scheduler's semantics: at
// one virtual instant, arrivals inject first, then the control loop
// decides, then the predictor rolls, then replicas step in index order.
const ARRIVALS: ComponentId = 0;
const CONTROL: ComponentId = 1;
const PREDICTOR: ComponentId = 2;
/// Replica `i` is component `REPLICA0 + i`; the resharder is appended
/// *after* the replicas (id `REPLICA0 + n`) so existing replica ids —
/// and therefore every tie-break in pre-shard-layer runs — are
/// unchanged. It is parked whenever no repartition window is open, so
/// runs that never reshard cost zero extra events.
const REPLICA0: ComponentId = 3;

/// N engine replicas + router + cluster precision control, drained from
/// the discrete-event core.
pub struct ClusterRouter<B: Backend> {
    replicas: Vec<Engine<B>>,
    router: Router,
    cfg: ClusterConfig,
    metrics: Vec<Metrics>,
    timelines: Vec<Vec<(f64, bool)>>,
    iterations: Vec<usize>,
    routed: Vec<usize>,
    /// Current escalation stage == number of replicas forced to FP8
    /// (legacy staged escalation only).
    stage: usize,
    stage_changed_at: f64,
    demotion_timeline: Vec<(f64, usize)>,
    /// The closed-loop controller (None = legacy staged escalation).
    autopilot: Option<Autopilot>,
    now: f64,
    // ---- event-core run state ---------------------------------------
    /// Workload not yet injected, sorted by arrival.
    pending: VecDeque<Request>,
    /// Completions accumulated across replica steps.
    completions: Vec<CompletedRequest>,
    /// Cached per-replica snapshots, refreshed at every mutation point
    /// (submit / step / directive change) so routing a single arrival is
    /// O(n) in the score scan but never rebuilds n engine scans. Debug
    /// builds cross-check the cache against fresh snapshots.
    snaps: Vec<ReplicaSnapshot>,
    control_ticks: Vec<f64>,
    control_tick_count: usize,
    control_ticks_head: Vec<f64>,
    control_ticks_tail: VecDeque<f64>,
    /// TP-transition state machine for every replica (Serving when the
    /// parallelism ladder is disabled — then it never schedules events).
    resharder: Resharder,
    /// The served model, when the backends know it (bills the
    /// weight-move term of repartition windows).
    model: Option<&'static ModelSpec>,
    events: EventStats,
}

impl<B: Backend> ClusterRouter<B> {
    /// Build a cluster: one [`Engine`] per backend, all sharing one
    /// [`ClusterConfig`] (per-replica engine settings are copied).
    ///
    /// # Examples
    ///
    /// ```
    /// use nestedfp::coordinator::backend::SimBackend;
    /// use nestedfp::coordinator::cluster::{ClusterConfig, ClusterRouter};
    /// use nestedfp::gpusim::WeightFormat;
    /// use nestedfp::model::zoo;
    ///
    /// let spec = zoo::find("llama31-8b").unwrap();
    /// let backends: Vec<SimBackend> = (0..2)
    ///     .map(|_| {
    ///         SimBackend::new(spec, WeightFormat::Nested16, WeightFormat::Nested8,
    ///                         8, 512, 512)
    ///     })
    ///     .collect();
    /// let mut cfg = ClusterConfig::default();
    /// cfg.engine.physical_kv = false; // simulation: KV accounting only
    /// let cluster = ClusterRouter::new(backends, cfg);
    /// assert_eq!(cluster.n_replicas(), 2);
    /// assert_eq!(cluster.forced_fp8_replicas(), 0);
    /// ```
    pub fn new(backends: Vec<B>, cfg: ClusterConfig) -> ClusterRouter<B> {
        assert!(!backends.is_empty(), "cluster needs at least one replica");
        let n = backends.len();
        if let Some(ap) = &cfg.autopilot {
            assert!(
                ap.max_tp <= cfg.engine.devices.max(1),
                "autopilot max_tp {} exceeds the replica device pool {}",
                ap.max_tp,
                cfg.engine.devices
            );
        }
        let model = backends[0].model_spec();
        let mut replicas: Vec<Engine<B>> = backends
            .into_iter()
            .map(|b| Engine::new(b, cfg.engine.clone()))
            .collect();
        for (i, e) in replicas.iter_mut().enumerate() {
            e.set_trace_track(i as u32);
        }
        let autopilot = cfg.autopilot.map(|ap_cfg| Autopilot::new(n, ap_cfg));
        let resharder = Resharder::new(n, cfg.reshard);
        ClusterRouter {
            router: Router::new(cfg.policy),
            replicas,
            cfg,
            metrics: (0..n).map(|_| Metrics::new()).collect(),
            timelines: vec![Vec::new(); n],
            iterations: vec![0; n],
            routed: vec![0; n],
            stage: 0,
            stage_changed_at: f64::NEG_INFINITY,
            demotion_timeline: Vec::new(),
            autopilot,
            now: 0.0,
            pending: VecDeque::new(),
            completions: Vec::new(),
            snaps: Vec::new(),
            control_ticks: Vec::new(),
            control_tick_count: 0,
            control_ticks_head: Vec::new(),
            control_ticks_tail: VecDeque::new(),
            resharder,
            model,
            events: EventStats::default(),
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// The cluster clock: the virtual time of the last dispatched event
    /// (0 before anything ran).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Replicas currently pinned to FP8 (staged escalation stage, or the
    /// count of FP8 directives under the autopilot).
    pub fn forced_fp8_replicas(&self) -> usize {
        match &self.autopilot {
            Some(ap) => ap
                .directives()
                .iter()
                .filter(|d| **d == PrecisionDirective::Fp8)
                .count(),
            None => self.stage,
        }
    }

    /// The closed-loop controller, when enabled (tests, benches).
    pub fn autopilot(&self) -> Option<&Autopilot> {
        self.autopilot.as_ref()
    }

    /// Direct access to a replica engine (tests, inspection).
    pub fn replica(&self, i: usize) -> &Engine<B> {
        &self.replicas[i]
    }

    /// Install one per-layer precision schedule on every replica engine
    /// (each gets its own clone; `None` clears). With a schedule and a
    /// fine autopilot ladder (`morph_rungs > 2`) interior rungs demote
    /// layer prefixes; without one the cluster behaves exactly as
    /// before — installation changes nothing snapshot-visible, so it is
    /// safe at any point, including before the first run.
    pub fn set_layer_schedule(&mut self, s: Option<&LayerSchedule>) {
        for e in &mut self.replicas {
            e.set_layer_schedule(s.cloned());
        }
    }

    /// The resharder's reshard state machine (tests, inspection).
    pub fn resharder(&self) -> &Resharder {
        &self.resharder
    }

    /// The resharder's component id: appended after the replicas.
    fn resharder_id(&self) -> ComponentId {
        REPLICA0 + self.replicas.len()
    }

    fn snapshot(&self, i: usize) -> ReplicaSnapshot {
        let e = &self.replicas[i];
        ReplicaSnapshot {
            free_kv_blocks: e.kv.free_blocks(),
            total_kv_blocks: e.kv.geo.total_blocks,
            active_requests: e.active_requests(),
            queued_requests: e.queued_requests(),
            ewma_tpot: e.controller.ewma_tpot(),
            tpot_target: e.config().slo.tpot_target,
            forced_fp8: e.controller.forced() == Some(Precision::Fp8),
            fp8_kv_blocks: e.kv.fp8_blocks(),
            host_kv_blocks: e.kv.host_blocks(),
            host_serving_lanes: e.host_serving_requests(),
            tp_degree: e.backend.tp_degree(),
            resharding: self.resharder.resharding(i),
        }
    }

    fn snapshots(&self) -> Vec<ReplicaSnapshot> {
        (0..self.replicas.len()).map(|i| self.snapshot(i)).collect()
    }

    fn refresh_snap(&mut self, i: usize) {
        self.snaps[i] = self.snapshot(i);
    }

    fn refresh_all_snaps(&mut self) {
        for i in 0..self.replicas.len() {
            self.snaps[i] = self.snapshot(i);
        }
    }

    /// Cross-check the snapshot cache against freshly built snapshots
    /// (debug builds only). Both drivers run through the same cache, so
    /// a missed refresh would be invisible to the equivalence suite —
    /// this tripwire is what catches it.
    fn debug_check_snaps(&self) {
        debug_assert_eq!(self.snapshots(), self.snaps, "stale replica snapshot cache");
    }

    /// Whether the control loop is a live component at all: the
    /// autopilot owns control when set; otherwise the staged escalation
    /// must have reachable thresholds ([`SurgeConfig::disabled`] has
    /// none, and then control costs zero events).
    fn control_enabled(&self) -> bool {
        self.autopilot.is_some() || self.cfg.surge.queue_per_stage.is_finite()
    }

    fn control_interval(&self) -> f64 {
        match &self.autopilot {
            Some(ap) => ap.config().control_interval_s,
            None => self.cfg.surge.control_interval_s,
        }
    }

    /// Any replica still holding active work (from the snapshot cache).
    fn fleet_active(&self) -> bool {
        self.snaps.iter().any(|s| s.active_requests > 0)
    }

    /// The control loop's next event after a tick at `now`: the exact
    /// interval cadence while the run is live, parked once the workload
    /// is fully injected and the fleet is idle (nothing left to govern).
    fn next_control_after(&self, now: f64) -> Option<f64> {
        if self.pending.is_empty() && !self.fleet_active() {
            None
        } else {
            Some(now + self.control_interval())
        }
    }

    // ---- event handlers (shared verbatim by both drivers) -----------

    /// Inject the next pending arrival: route it, feed the predictor,
    /// submit to the chosen replica, and wake that replica at its engine
    /// clock (an idle replica's clock may lag; submission "wakes" it).
    fn inject_arrival(&mut self, now: f64, wake: &mut Waker) {
        self.now = now;
        self.events.arrival_events += 1;
        let r = self.pending.pop_front().expect("arrival event without a pending request");
        debug_assert_eq!(r.arrival.to_bits(), now.to_bits());
        self.debug_check_snaps();
        let i = self.router.pick(&self.snaps);
        self.routed[i] += 1;
        if let Some(ap) = self.autopilot.as_mut() {
            // the predictor sees the arrival-rate series online, exactly
            // as routed — no lookahead into `pending`
            ap.observe_arrival(r.arrival);
        }
        self.replicas[i].set_clock(r.arrival);
        self.replicas[i].submit(r);
        self.refresh_snap(i);
        wake.wake_at(REPLICA0 + i, self.replicas[i].now());
    }

    /// One control tick at its scheduled virtual time: the autopilot's
    /// control law, or the legacy staged escalation. Called without any
    /// `due()` float gate — the event schedule *is* the cadence (the
    /// pre-event-core driver gated on `due()` from whatever iteration
    /// time happened to be near, which both skewed tick times and
    /// skipped ticks entirely across arrival droughts).
    fn control_tick(&mut self, now: f64, wake: &mut Waker) {
        self.now = now;
        self.events.control_events += 1;
        self.control_tick_count += 1;
        if self.cfg.record_control_ticks {
            self.control_ticks.push(now);
        }
        if self.control_ticks_head.len() < 16 {
            self.control_ticks_head.push(now);
        }
        self.control_ticks_tail.push_back(now);
        if self.control_ticks_tail.len() > 16 {
            self.control_ticks_tail.pop_front();
        }
        if self.autopilot.is_some() {
            self.debug_check_snaps();
            let snaps = &self.snaps;
            let ap = self.autopilot.as_mut().expect("autopilot enabled");
            // trace bookkeeping only: captured so rung changes and
            // predictor pre-escalations can be emitted as instants below
            let prev_dirs = trace::enabled().then(|| ap.directives());
            let prev_pre = ap.pre_escalations;
            let dirs = ap.control_with_snapshots(now, snaps);
            let tp_targets = ap.tp_targets();
            let post_pre = ap.pre_escalations;
            if let Some(prev) = prev_dirs {
                for (i, (p, d)) in prev.iter().zip(&dirs).enumerate() {
                    if p != d {
                        trace::instant(
                            trace::CONTROL_TRACK,
                            Kind::Rung,
                            now,
                            i as u64,
                            d.rung() as i64,
                        );
                    }
                }
                if post_pre > prev_pre {
                    trace::instant(
                        trace::CONTROL_TRACK,
                        Kind::PreEscalate,
                        now,
                        0,
                        (post_pre - prev_pre) as i64,
                    );
                }
            }
            let fp8 = dirs
                .iter()
                .filter(|d| **d == PrecisionDirective::Fp8)
                .count();
            // fine ladder (morph_rungs > 2): walk each replica's
            // controller by rung — endpoints are bit-identical to the
            // coarse directives; interior rungs pin partial schedules.
            // The coarse path applies the three-rung directive exactly
            // as before.
            match ap.fine_rungs() {
                Some((states, max_rung)) => {
                    for (i, e) in self.replicas.iter_mut().enumerate() {
                        e.controller.apply_layer_rung(states[i], max_rung);
                    }
                }
                None => {
                    for (e, d) in self.replicas.iter_mut().zip(&dirs) {
                        e.controller.apply_directive(*d);
                    }
                }
            }
            // reconcile actual TP degrees toward the parallelism
            // ladder's targets: a mismatched serving replica starts a
            // drain; anything mid-window is left alone (the next tick
            // re-checks — the ladder's dwell discipline keeps targets
            // stable across a window). At most one replica reshards at
            // a time: a drain freezes admission, so letting the whole
            // fleet drain simultaneously would stall every arrival
            // behind frozen queues — serializing windows caps the
            // availability loss at one replica, and the ladder's
            // persistent targets let the others catch up at later
            // ticks.
            for i in 0..self.replicas.len() {
                if self.resharder.any_pending() {
                    break;
                }
                let want = tp_targets[i];
                if want != self.replicas[i].backend.tp_degree()
                    && self.resharder.begin(i, want)
                {
                    trace::begin(trace::CONTROL_TRACK, Kind::Reshard, now, i as u64, want as i64);
                    self.replicas[i].set_admission_frozen(true);
                    // a replica with no admitted work drains instantly
                    self.try_open_window(i, now, wake);
                }
            }
            self.refresh_all_snaps();
            let changed = self
                .demotion_timeline
                .last()
                .map(|&(_, k)| k != fp8)
                .unwrap_or(fp8 > 0);
            if changed {
                self.demotion_timeline.push((now, fp8));
            }
        } else {
            let due_soon = self
                .pending
                .iter()
                .take_while(|r| r.arrival <= now + 0.02)
                .count();
            self.update_escalation(now, due_soon);
        }
    }

    /// Advance the surge predictor's bucket clock (autopilot runs only).
    /// Observationally neutral to the control law — `boost` rolls to
    /// `now` itself — but keeps `rates()` reads current through arrival
    /// droughts and gives the predictor its own event stream.
    fn predictor_tick(&mut self, now: f64) -> Option<f64> {
        self.now = now;
        self.events.predictor_events += 1;
        let live = !self.pending.is_empty() || self.fleet_active();
        let ap = self
            .autopilot
            .as_mut()
            .expect("predictor clock scheduled without an autopilot");
        ap.roll_predictor_to(now);
        if live {
            Some(ap.next_predictor_boundary())
        } else {
            None
        }
    }

    /// One replica event: step the engine at its own clock. Returns the
    /// replica's next event time — its new clock while it holds active
    /// work, a re-arm at the next arrival when blocked, `None` (parked)
    /// when drained.
    fn replica_tick(&mut self, i: usize, now: f64, wake: &mut Waker) -> Result<Option<f64>> {
        self.now = now;
        if self.replicas[i].is_idle() {
            // contract tripwire: parked replicas must receive no events
            self.events.idle_replica_events += 1;
            return Ok(None);
        }
        self.events.replica_step_events += 1;
        let t0 = self.replicas[i].now();
        debug_assert_eq!(t0.to_bits(), now.to_bits());
        // each replica will receive only ~1/N of the imminent arrivals,
        // so feed its local controller the per-replica share — the full
        // count would push every Dual controller over its queue
        // threshold at once and defeat *selective* demotion (the
        // cluster-wide signal lives in escalation)
        let imminent = self
            .pending
            .iter()
            .take_while(|r| r.arrival <= t0 + 0.02)
            .count()
            .div_ceil(self.replicas.len());
        let step = self.replicas[i].step(imminent, &mut self.metrics[i])?;
        if let Some(ap) = self.autopilot.as_mut() {
            ap.observe_step(i, self.replicas[i].now(), &step);
        }
        if self.timelines[i]
            .last()
            .map(|&(_, last)| last != step.fp8)
            .unwrap_or(true)
        {
            self.timelines[i].push((t0, step.fp8));
        }
        let next = if step.ran {
            self.iterations[i] += 1;
            self.completions.extend(step.completions);
            let e = &self.replicas[i];
            (e.active_requests() > 0).then(|| e.now())
        } else {
            self.events.replica_blocked_wakes += 1;
            if self.replicas[i].admission_frozen() {
                // reshard drain: only queued (unadmitted) work is left
                // and the freeze — not time — is what blocks it. Park;
                // the window's close unfreezes admission and wakes us.
                None
            } else {
                // replica i has queued work it cannot admit and no
                // decode in flight; only time (the next arrival) can
                // change that
                match self.pending.front() {
                    Some(next_req) => {
                        let t = next_req.arrival.max(t0 + 1e-4);
                        self.replicas[i].set_clock(t);
                        Some(self.replicas[i].now())
                    }
                    None => {
                        return Err(anyhow!(
                            "cluster deadlock: replica {i} has {} active requests \
                             but nothing runnable and no arrivals left",
                            self.replicas[i].active_requests()
                        ));
                    }
                }
            }
        };
        // a draining replica whose last admitted request just finished
        // (or which had none) opens its repartition window at its own
        // engine clock — the drain is billed at replica time, not at
        // whatever event time happened to dispatch us
        if self.resharder.resharding(i) {
            let t = self.replicas[i].now().max(now);
            self.try_open_window(i, t, wake);
        }
        self.refresh_snap(i);
        Ok(next)
    }

    /// If the draining replica `i` has no admitted work left, open its
    /// repartition window at `t` and arm the resharder component at the
    /// window's deadline.
    fn try_open_window(&mut self, i: usize, t: f64, wake: &mut Waker) {
        if matches!(self.resharder.state(i), ReshardState::Draining { .. })
            && self.replicas[i].admitted_requests() == 0
        {
            let from = ShardPlan {
                devices: self.cfg.engine.devices.max(1),
                tp: self.replicas[i].backend.tp_degree(),
            };
            let until = self.resharder.drained(i, t, self.model, from);
            wake.wake_at(self.resharder_id(), until);
        }
    }

    /// One resharder event: close every repartition window due at `now`.
    /// Each closed window's replica switches its backend to the new TP
    /// degree, unfreezes admission, and — if it still owns work — wakes
    /// to admit its queue at the new degree.
    fn resharder_tick(&mut self, now: f64, wake: &mut Waker) -> Option<f64> {
        self.now = now;
        self.events.reshard_events += 1;
        for (i, tp) in self.resharder.complete_due(now) {
            trace::end(trace::CONTROL_TRACK, Kind::Reshard, now, i as u64, tp as i64);
            self.replicas[i].backend.set_tp_degree(tp);
            self.replicas[i].set_admission_frozen(false);
            if self.replicas[i].active_requests() > 0 {
                self.replicas[i].set_clock(now);
                wake.wake_at(REPLICA0 + i, self.replicas[i].now());
            }
            self.refresh_snap(i);
        }
        self.resharder.next_deadline()
    }

    /// Staged escalation: compare cluster queue pressure (queued requests
    /// per replica, including imminent arrivals) against the per-stage
    /// thresholds; demote/release the tail replicas accordingly. Replica 0
    /// is demoted last, so it keeps FP16 quality the longest.
    fn update_escalation(&mut self, now: f64, imminent_arrivals: usize) {
        let n = self.replicas.len();
        let queued: usize = self
            .replicas
            .iter()
            .map(|e| e.queued_requests())
            .sum::<usize>()
            + imminent_arrivals;
        let pressure = queued as f64 / n as f64;
        let s = self.cfg.surge;

        let mut want = self.stage;
        if pressure >= s.queue_per_stage * (self.stage + 1) as f64 {
            // engage every stage whose threshold the pressure clears
            want = ((pressure / s.queue_per_stage).floor() as usize).min(n);
        } else if self.stage > 0
            && pressure <= s.release_frac * s.queue_per_stage * self.stage as f64
        {
            // release one stage at a time
            want = self.stage - 1;
        }
        if want != self.stage && now - self.stage_changed_at >= s.min_dwell_s {
            self.stage = want;
            self.stage_changed_at = now;
            let stage = self.stage;
            for (i, e) in self.replicas.iter_mut().enumerate() {
                let demote = i >= n - stage;
                e.controller
                    .set_forced(if demote { Some(Precision::Fp8) } else { None });
            }
            self.refresh_all_snaps();
            self.demotion_timeline.push((now, stage));
            trace::instant(trace::CONTROL_TRACK, Kind::Rung, now, stage as u64, stage as i64);
        }
    }

    // ---- run drivers ------------------------------------------------

    fn begin(&mut self, mut workload: Vec<Request>) {
        workload.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        self.pending = VecDeque::from(workload);
        self.completions = Vec::new();
        self.resharder = Resharder::new(self.replicas.len(), self.cfg.reshard);
        self.snaps = self.snapshots();
        self.control_ticks = Vec::new();
        self.control_tick_count = 0;
        self.control_ticks_head = Vec::new();
        self.control_ticks_tail = VecDeque::new();
        self.events = EventStats::default();
    }

    fn components(n: usize) -> Vec<Box<dyn Component<Self>>> {
        let mut cs: Vec<Box<dyn Component<Self>>> = vec![
            Box::new(ArrivalInjector),
            Box::new(ControlLoop),
            Box::new(PredictorClock),
        ];
        for i in 0..n {
            cs.push(Box::new(ReplicaComponent { i }));
        }
        // appended after the replicas so their ids — and every event
        // tie-break of a run that never reshards — are unchanged
        cs.push(Box::new(ResharderComponent));
        cs
    }

    /// Replay a whole workload (requests with arrival timestamps) across
    /// the cluster to completion and report per-replica + aggregate
    /// metrics. Drained through the event core's binary-heap driver;
    /// single-shot — build a fresh cluster per run.
    pub fn run(&mut self, workload: Vec<Request>) -> Result<ClusterReport> {
        self.begin(workload);
        let mut components = Self::components(self.replicas.len());
        let queue_stats = event_core::drive(&mut components, self)?;
        self.events.queue = queue_stats;
        // requests still in flight at the horizon leave open spans;
        // close them at the final clock so exports stay balanced
        trace::finish_run(self.now);
        self.build_report()
    }

    /// [`ClusterRouter::run`] through the naive-scan lockstep oracle
    /// instead of the binary heap — identical component semantics,
    /// O(components) scan per event. Test-only surface (the equivalence
    /// suite pins `run` against it bit-for-bit); hidden from docs so
    /// nobody reaches for it in production code.
    #[doc(hidden)]
    pub fn run_lockstep(&mut self, workload: Vec<Request>) -> Result<ClusterReport> {
        self.begin(workload);
        let mut components = Self::components(self.replicas.len());
        let queue_stats = event_core::drive_lockstep(&mut components, self)?;
        self.events.queue = queue_stats;
        trace::finish_run(self.now);
        self.build_report()
    }

    fn build_report(&mut self) -> Result<ClusterReport> {
        if let Some(ap) = self.autopilot.as_mut() {
            ap.finish(self.now);
        }
        let n = self.replicas.len();
        let mut replicas = Vec::with_capacity(n);
        for i in 0..n {
            let (mode_stats, directive_timeline) = match &self.autopilot {
                Some(ap) => (ap.mode_stats(i), ap.directive_timeline(i).to_vec()),
                None => (ModeStats::default(), Vec::new()),
            };
            let mut metrics = std::mem::replace(&mut self.metrics[i], Metrics::new());
            metrics.observe_modes(mode_stats.dwell_s, mode_stats.switches);
            let e = &self.replicas[i];
            replicas.push(ReplicaReport {
                metrics,
                controller: e.controller.clone(),
                mode_timeline: std::mem::take(&mut self.timelines[i]),
                iterations: self.iterations[i],
                routed: self.routed[i],
                mode_stats,
                directive_timeline,
                final_free_kv_blocks: e.kv.free_blocks(),
                final_host_kv_blocks: e.kv.host_blocks(),
                total_kv_blocks: e.kv.geo.total_blocks,
                final_tp_degree: e.backend.tp_degree(),
            });
        }
        let mut aggregate = Metrics::new();
        for r in &replicas {
            aggregate.merge(&r.metrics);
        }
        // reshard counters are cluster-owned (the resharder is shared),
        // so they land on the aggregate directly rather than per replica
        aggregate.observe_reshards(self.resharder.reshards, self.resharder.repartition_s);
        // fold the run into the thread-local global registry, which
        // `repro reproduce --json` dumps as one flat counter object
        crate::telemetry::registry::with_global(|g| {
            g.merge(&aggregate.scalar_registry());
            let mut ev = crate::telemetry::Registry::new();
            self.events.register_into(&mut ev, "events");
            g.merge(&ev);
        });
        Ok(ClusterReport {
            replicas,
            aggregate,
            completions: std::mem::take(&mut self.completions),
            demotion_timeline: self.demotion_timeline.clone(),
            ladder_timeline: self
                .autopilot
                .as_ref()
                .map(|ap| ap.ladder_timeline.clone())
                .unwrap_or_default(),
            pre_escalations: self
                .autopilot
                .as_ref()
                .map(|ap| ap.pre_escalations)
                .unwrap_or(0),
            control_ticks: std::mem::take(&mut self.control_ticks),
            control_tick_count: self.control_tick_count,
            control_ticks_head: std::mem::take(&mut self.control_ticks_head),
            control_ticks_tail: std::mem::take(&mut self.control_ticks_tail).into(),
            reshard_timeline: self.resharder.timeline.clone(),
            events: self.events,
        })
    }
}

// ---- the cluster's components ---------------------------------------

/// Component 0: pops one pending request per event at its arrival time.
/// Same-time arrivals drain back-to-back before anything else at that
/// instant (id 0 wins every tie), so routing still sees arrival order.
struct ArrivalInjector;

impl<B: Backend> Component<ClusterRouter<B>> for ArrivalInjector {
    fn next_tick(&self, sys: &ClusterRouter<B>) -> Option<f64> {
        sys.pending.front().map(|r| r.arrival)
    }
    fn tick(
        &mut self,
        now: f64,
        sys: &mut ClusterRouter<B>,
        wake: &mut Waker,
    ) -> Result<Option<f64>> {
        sys.inject_arrival(now, wake);
        Ok(sys.pending.front().map(|r| r.arrival))
    }
}

/// Component 1: the precision control loop (autopilot or staged
/// escalation), first firing with the first arrival and then at exactly
/// `control_interval_s` spacing while the run is live.
struct ControlLoop;

impl<B: Backend> Component<ClusterRouter<B>> for ControlLoop {
    fn next_tick(&self, sys: &ClusterRouter<B>) -> Option<f64> {
        if !sys.control_enabled() {
            return None;
        }
        sys.pending.front().map(|r| r.arrival)
    }
    fn tick(
        &mut self,
        now: f64,
        sys: &mut ClusterRouter<B>,
        wake: &mut Waker,
    ) -> Result<Option<f64>> {
        sys.control_tick(now, wake);
        Ok(sys.next_control_after(now))
    }
}

/// Component 2: the surge predictor's one-second bucket clock (autopilot
/// runs only; parked otherwise).
struct PredictorClock;

impl<B: Backend> Component<ClusterRouter<B>> for PredictorClock {
    fn next_tick(&self, sys: &ClusterRouter<B>) -> Option<f64> {
        let ap = sys.autopilot.as_ref()?;
        sys.pending
            .front()
            .map(|r| ap.predictor_boundary_after(r.arrival))
    }
    fn tick(
        &mut self,
        now: f64,
        sys: &mut ClusterRouter<B>,
        _wake: &mut Waker,
    ) -> Result<Option<f64>> {
        Ok(sys.predictor_tick(now))
    }
}

/// Components 3..3+N: one per replica engine, scheduled at the engine's
/// own clock whenever it holds active work, parked otherwise.
struct ReplicaComponent {
    i: usize,
}

impl<B: Backend> Component<ClusterRouter<B>> for ReplicaComponent {
    fn next_tick(&self, sys: &ClusterRouter<B>) -> Option<f64> {
        let e = &sys.replicas[self.i];
        (!e.is_idle()).then(|| e.now())
    }
    fn tick(
        &mut self,
        now: f64,
        sys: &mut ClusterRouter<B>,
        wake: &mut Waker,
    ) -> Result<Option<f64>> {
        sys.replica_tick(self.i, now, wake)
    }
}

/// Component 3+N: the resharder's repartition-window deadline clock.
/// Parked (no events, zero cost) whenever no window is open — a run
/// that never moves the parallelism knob never dispatches it.
struct ResharderComponent;

impl<B: Backend> Component<ClusterRouter<B>> for ResharderComponent {
    fn next_tick(&self, sys: &ClusterRouter<B>) -> Option<f64> {
        sys.resharder.next_deadline()
    }
    fn tick(
        &mut self,
        now: f64,
        sys: &mut ClusterRouter<B>,
        wake: &mut Waker,
    ) -> Result<Option<f64>> {
        Ok(sys.resharder_tick(now, wake))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::StepRun;
    use crate::coordinator::kv::{KvCacheManager, KvGeometry};
    use crate::coordinator::precision::{PrecisionPolicy, SloConfig};

    /// Fixed-latency backend producing no logits (requests run to their
    /// output budget), enough to exercise cluster scheduling.
    struct TestBackend {
        geo: KvGeometry,
        latency: f64,
        tp: usize,
    }

    impl TestBackend {
        fn new(latency: f64) -> TestBackend {
            TestBackend {
                geo: KvGeometry {
                    n_layers: 1,
                    n_heads: 1,
                    max_seq: 128,
                    head_dim: 1,
                    block_size: 8,
                    total_blocks: 256,
                },
                latency,
                tp: 1,
            }
        }
        /// Sharded steps run proportionally faster (perfectly linear —
        /// the sublinear law lives in `gpusim::step_latency_tp`; the
        /// cluster tests only need *a* speedup). `x / 1.0 == x` exactly,
        /// so tp = 1 runs are bit-identical to the pre-shard backend.
        fn step_latency(&self) -> f64 {
            self.latency / self.tp as f64
        }
    }

    impl Backend for TestBackend {
        fn geometry(&self) -> KvGeometry {
            self.geo
        }
        fn prefill_chunks(&self) -> Vec<usize> {
            vec![8, 16]
        }
        fn max_decode_batch(&self) -> usize {
            4
        }
        fn tp_degree(&self) -> usize {
            self.tp
        }
        fn set_tp_degree(&mut self, tp: usize) {
            self.tp = tp;
        }
        fn prefill(
            &mut self,
            _kv: &mut KvCacheManager,
            _slot: usize,
            _start: usize,
            _tokens: &[i32],
            _p: Precision,
        ) -> Result<StepRun> {
            Ok(StepRun {
                logits: None,
                latency: self.step_latency(),
                ..StepRun::default()
            })
        }
        fn decode(
            &mut self,
            _kv: &mut KvCacheManager,
            _slots: &[usize],
            _tokens: &[i32],
            _pos: &[i32],
            _p: Precision,
        ) -> Result<StepRun> {
            Ok(StepRun {
                logits: None,
                latency: self.step_latency(),
                ..StepRun::default()
            })
        }
    }

    fn cluster(n: usize, latency: f64, cfg: ClusterConfig) -> ClusterRouter<TestBackend> {
        let backends: Vec<TestBackend> = (0..n).map(|_| TestBackend::new(latency)).collect();
        ClusterRouter::new(backends, cfg)
    }

    fn sim_engine_cfg(policy: PrecisionPolicy) -> EngineConfig {
        EngineConfig {
            policy,
            slo: SloConfig::default(),
            physical_kv: false,
            max_iterations: 0,
            kv: crate::kvcache::KvPressureConfig::default(),
            devices: 1,
        }
    }

    fn burst(n: usize, at: f64) -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i as u64, vec![1; 16], 8, at))
            .collect()
    }

    #[test]
    fn round_robin_splits_the_workload() {
        let cfg = ClusterConfig {
            policy: RoutingPolicy::RoundRobin,
            engine: sim_engine_cfg(PrecisionPolicy::Fp16Only),
            surge: SurgeConfig::default(),
            autopilot: None,
            ..ClusterConfig::default()
        };
        let mut c = cluster(2, 0.001, cfg);
        let report = c.run(burst(6, 0.0)).unwrap();
        assert_eq!(report.aggregate.completed, 6);
        assert_eq!(report.replicas[0].routed, 3);
        assert_eq!(report.replicas[1].routed, 3);
        assert_eq!(report.aggregate.total_output_tokens, 48);
    }

    #[test]
    fn cluster_runs_are_deterministic() {
        let make = || {
            let cfg = ClusterConfig {
                policy: RoutingPolicy::Random { seed: 77 },
                engine: sim_engine_cfg(PrecisionPolicy::Dual),
                surge: SurgeConfig::default(),
                autopilot: None,
                ..ClusterConfig::default()
            };
            cluster(3, 0.004, cfg)
        };
        let mut workload = burst(12, 0.0);
        workload.extend(
            (0..6).map(|i| Request::new(100 + i as u64, vec![1; 16], 8, 0.5 + 0.1 * i as f64)),
        );
        let a = make().run(workload.clone()).unwrap();
        let b = make().run(workload).unwrap();
        let ids = |r: &ClusterReport| -> Vec<u64> { r.completions.iter().map(|c| c.id).collect() };
        assert_eq!(ids(&a), ids(&b), "same seed, same dispatch, same order");
        let routed = |r: &ClusterReport| -> Vec<usize> {
            r.replicas.iter().map(|x| x.routed).collect()
        };
        assert_eq!(routed(&a), routed(&b));
        assert_eq!(a.aggregate.completed, b.aggregate.completed);
    }

    #[test]
    fn least_loaded_prefers_the_freer_replica() {
        let cfg = ClusterConfig {
            policy: RoutingPolicy::LeastLoadedKv,
            engine: sim_engine_cfg(PrecisionPolicy::Fp16Only),
            surge: SurgeConfig::default(),
            autopilot: None,
            ..ClusterConfig::default()
        };
        let mut c = cluster(2, 0.050, cfg);
        // first request lands on replica 0 (tie); by the second arrival
        // replica 0 holds KV blocks, so replica 1 has more free blocks
        let workload = vec![
            Request::new(1, vec![1; 16], 8, 0.0),
            Request::new(2, vec![1; 16], 8, 0.3),
        ];
        let report = c.run(workload).unwrap();
        assert_eq!(report.replicas[0].routed, 1);
        assert_eq!(report.replicas[1].routed, 1);
        assert_eq!(report.aggregate.completed, 2);
    }

    #[test]
    fn surge_demotes_exactly_the_intended_replicas() {
        // FP16-only engines: any FP8 iteration must come from the
        // cluster's staged escalation, nowhere else.
        let cfg = ClusterConfig {
            policy: RoutingPolicy::RoundRobin,
            engine: sim_engine_cfg(PrecisionPolicy::Fp16Only),
            surge: SurgeConfig {
                queue_per_stage: 2.0,
                release_frac: 0.5,
                min_dwell_s: 0.0,
                control_interval_s: 0.25,
            },
            autopilot: None,
            ..ClusterConfig::default()
        };
        let mut c = cluster(3, 0.002, cfg);
        // 8 simultaneous arrivals -> pressure 8/3 = 2.67 -> stage 1:
        // only the highest-indexed replica (2) is demoted
        let report = c.run(burst(8, 0.0)).unwrap();
        assert!(
            !report.demotion_timeline.is_empty(),
            "surge never triggered escalation"
        );
        let (_, first_stage) = report.demotion_timeline[0];
        assert_eq!(first_stage, 1, "pressure 2.67 must engage exactly stage 1");
        assert_eq!(
            report.replicas[0].controller.iters_fp8, 0,
            "replica 0 must stay FP16"
        );
        assert_eq!(
            report.replicas[1].controller.iters_fp8, 0,
            "replica 1 must stay FP16"
        );
        assert!(
            report.replicas[2].controller.iters_fp8 > 0,
            "replica 2 (the demotion target) never served FP8"
        );
        // stages release as the queue drains
        assert_eq!(report.demotion_timeline.last().unwrap().1, 0);
        assert_eq!(report.aggregate.completed, 8);
    }

    #[test]
    fn autopilot_escalates_under_a_burst_and_accounts_dwell() {
        // FP16-only engines + autopilot: any FP8 iteration can only come
        // from the autopilot's pinned-FP8 directives.
        let cfg = ClusterConfig {
            policy: RoutingPolicy::RoundRobin,
            engine: sim_engine_cfg(PrecisionPolicy::Fp16Only),
            surge: SurgeConfig::disabled(),
            autopilot: Some(AutopilotConfig::default()),
            ..ClusterConfig::default()
        };
        let mut c = cluster(2, 0.020, cfg);
        // 14 simultaneous arrivals with enough decode work (~1 s of
        // virtual time per replica) for the ladder to walk FP16 → Mixed
        // → FP8 past both escalate dwells; queue + gap pressure crosses
        // the threshold from the first control tick
        let reqs: Vec<Request> = (0..14)
            .map(|i| Request::new(i as u64, vec![1; 16], 24, 0.0))
            .collect();
        let report = c.run(reqs).unwrap();
        assert_eq!(report.aggregate.completed, 14);
        assert!(
            !report.ladder_timeline.is_empty(),
            "burst never moved the cluster ladder"
        );
        assert!(
            report.replicas.iter().any(|r| r.controller.iters_fp8 > 0),
            "no replica was ever pinned to FP8"
        );
        assert!(report.aggregate.mode_switches > 0);
        // dwell accounting: every replica is billed the same span (run
        // start to run end), split across the three rungs
        let spans: Vec<f64> = report
            .replicas
            .iter()
            .map(|r| r.mode_stats.dwell_s.iter().sum::<f64>())
            .collect();
        assert!(spans[0] > 0.0);
        assert!(
            (spans[0] - spans[1]).abs() < 1e-6,
            "replica dwell spans diverged: {spans:?}"
        );
        // the aggregate merges dwell by sum
        let agg: f64 = report.aggregate.mode_dwell_s.iter().sum();
        assert!((agg - (spans[0] + spans[1])).abs() < 1e-6);
        // drained cluster leaks no KV anywhere
        for r in &report.replicas {
            assert_eq!(r.final_free_kv_blocks, r.total_kv_blocks);
            assert_eq!(r.final_host_kv_blocks, 0);
        }
    }

    #[test]
    fn autopilot_runs_are_deterministic() {
        let make = || {
            let cfg = ClusterConfig {
                policy: RoutingPolicy::SloHeadroom,
                engine: sim_engine_cfg(PrecisionPolicy::Dual),
                surge: SurgeConfig::disabled(),
                autopilot: Some(AutopilotConfig::default()),
                ..ClusterConfig::default()
            };
            cluster(3, 0.008, cfg)
        };
        let mut workload = burst(10, 0.0);
        workload.extend(
            (0..8).map(|i| Request::new(100 + i as u64, vec![1; 16], 8, 0.3 + 0.2 * i as f64)),
        );
        let a = make().run(workload.clone()).unwrap();
        let b = make().run(workload).unwrap();
        let ids = |r: &ClusterReport| -> Vec<u64> { r.completions.iter().map(|c| c.id).collect() };
        assert_eq!(ids(&a), ids(&b));
        assert_eq!(a.ladder_timeline, b.ladder_timeline);
        assert_eq!(a.pre_escalations, b.pre_escalations);
        assert_eq!(a.aggregate.mode_switches, b.aggregate.mode_switches);
        assert_eq!(a.control_ticks, b.control_ticks);
        assert_eq!(a.events, b.events);
        for (x, y) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(x.directive_timeline, y.directive_timeline);
        }
    }

    #[test]
    fn more_replicas_absorb_the_same_surge_better() {
        let run_with = |n: usize| {
            let cfg = ClusterConfig {
                policy: RoutingPolicy::RoundRobin,
                engine: sim_engine_cfg(PrecisionPolicy::Fp16Only),
                surge: SurgeConfig::default(),
                autopilot: None,
                ..ClusterConfig::default()
            };
            let mut c = cluster(n, 0.010, cfg);
            c.run(burst(8, 0.0)).unwrap()
        };
        let mut one = run_with(1);
        let mut four = run_with(4);
        assert_eq!(one.aggregate.completed, 8);
        assert_eq!(four.aggregate.completed, 8);
        let s1 = one.aggregate.ttft_summary();
        let s4 = four.aggregate.ttft_summary();
        assert!(
            s4.max < s1.max,
            "4 replicas should cut worst TTFT: {} !< {}",
            s4.max,
            s1.max
        );
    }

    /// The tentpole invariant, pinned in-module on the cheap backend
    /// (the SimBackend version lives in `rust/tests/event_core_props.rs`):
    /// the heap driver and the lockstep oracle produce bit-identical
    /// cluster runs.
    #[test]
    fn event_driver_matches_lockstep_oracle() {
        let make = || {
            let cfg = ClusterConfig {
                policy: RoutingPolicy::SloHeadroom,
                engine: sim_engine_cfg(PrecisionPolicy::Dual),
                surge: SurgeConfig::disabled(),
                autopilot: Some(AutopilotConfig::default()),
                ..ClusterConfig::default()
            };
            cluster(3, 0.008, cfg)
        };
        let mut workload = burst(10, 0.0);
        workload.extend(
            (0..8).map(|i| Request::new(100 + i as u64, vec![1; 16], 12, 0.3 + 0.2 * i as f64)),
        );
        let a = make().run(workload.clone()).unwrap();
        let b = make().run_lockstep(workload).unwrap();
        let ids = |r: &ClusterReport| -> Vec<u64> { r.completions.iter().map(|c| c.id).collect() };
        assert_eq!(ids(&a), ids(&b));
        let bits = |xs: &[f64]| -> Vec<u64> { xs.iter().map(|x| x.to_bits()).collect() };
        assert_eq!(bits(&a.control_ticks), bits(&b.control_ticks));
        assert_eq!(a.ladder_timeline, b.ladder_timeline);
        assert_eq!(a.aggregate.completed, b.aggregate.completed);
        assert_eq!(
            a.aggregate.total_output_tokens,
            b.aggregate.total_output_tokens
        );
        for (x, y) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(x.routed, y.routed);
            assert_eq!(x.iterations, y.iterations);
            assert_eq!(x.directive_timeline, y.directive_timeline);
        }
        // dispatch counters agree (heap lazy deletions excepted)
        assert_eq!(a.events.arrival_events, b.events.arrival_events);
        assert_eq!(a.events.control_events, b.events.control_events);
        assert_eq!(a.events.predictor_events, b.events.predictor_events);
        assert_eq!(a.events.replica_step_events, b.events.replica_step_events);
        assert_eq!(a.events.queue.popped, b.events.queue.popped);
    }

    /// Idle replicas are parked, not polled: a one-request workload on a
    /// wide cluster must dispatch zero events to the replicas that never
    /// receive work.
    #[test]
    fn idle_replicas_cost_zero_events() {
        let cfg = ClusterConfig {
            policy: RoutingPolicy::LeastLoadedKv,
            engine: sim_engine_cfg(PrecisionPolicy::Fp16Only),
            surge: SurgeConfig::disabled(),
            autopilot: None,
            ..ClusterConfig::default()
        };
        let mut c = cluster(8, 0.002, cfg);
        let report = c.run(vec![Request::new(1, vec![1; 16], 8, 0.0)]).unwrap();
        assert_eq!(report.aggregate.completed, 1);
        assert_eq!(report.events.idle_replica_events, 0);
        assert_eq!(report.events.arrival_events, 1);
        // control + predictor are disabled here, so every popped event
        // is the arrival or a step of the one working replica
        assert_eq!(report.events.control_events, 0);
        assert_eq!(report.events.predictor_events, 0);
        assert_eq!(
            report.events.queue.popped as usize,
            1 + report.events.replica_step_events
        );
        let working: usize = report.replicas.iter().filter(|r| r.iterations > 0).count();
        assert_eq!(working, 1, "exactly one replica should ever run");
    }

    /// Config for the reshard tests: precision pinned at FP16
    /// (`max_precision_rung: 0`) so queue pressure flows straight into
    /// the parallelism ladder, over a 2-device pool.
    fn tp_cluster_cfg() -> ClusterConfig {
        let mut engine = sim_engine_cfg(PrecisionPolicy::Fp16Only);
        engine.devices = 2;
        ClusterConfig {
            policy: RoutingPolicy::RoundRobin,
            engine,
            surge: SurgeConfig::disabled(),
            autopilot: Some(AutopilotConfig {
                max_precision_rung: 0,
                max_tp: 2,
                ..AutopilotConfig::default()
            }),
            ..ClusterConfig::default()
        }
    }

    /// The reshard lifecycle end to end on the cheap backend: a burst
    /// pressures both replicas, the parallelism ladder escalates, the
    /// resharder drains → repartitions → resumes each replica at tp 2,
    /// and every request submitted before, during, and after the window
    /// completes exactly once.
    #[test]
    fn reshard_window_drains_and_resumes_without_losing_requests() {
        let mut c = cluster(2, 0.020, tp_cluster_cfg());
        let mut workload: Vec<Request> = (0..14)
            .map(|i| Request::new(i as u64, vec![1; 16], 24, 0.0))
            .collect();
        // arrivals that land inside and after the reshard windows
        workload.extend(
            (0..6).map(|i| Request::new(100 + i as u64, vec![1; 16], 8, 0.01 + 0.1 * i as f64)),
        );
        let report = c.run(workload).unwrap();
        assert_eq!(report.aggregate.completed, 20, "requests lost across reshard");
        let ids: std::collections::HashSet<u64> =
            report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids.len(), 20, "a request completed twice");
        assert!(
            report.aggregate.reshards >= 1,
            "queue pressure never triggered a reshard"
        );
        assert_eq!(report.aggregate.reshards, report.reshard_timeline.len());
        assert!(report.aggregate.reshard_repartition_s > 0.0);
        assert!(report.events.reshard_events >= 1);
        // the timeline records the resume at the escalated degree, and
        // the replicas end the run actually sharded
        assert!(report.reshard_timeline.iter().any(|&(_, _, tp)| tp == 2));
        assert!(report.replicas.iter().any(|r| r.final_tp_degree == 2));
    }

    /// Bit-identity of the heap driver vs the lockstep oracle must
    /// survive reshard events: the resharder component's window
    /// deadlines, the frozen-replica parks, and the resume wakes all
    /// replay identically.
    #[test]
    fn lockstep_oracle_agrees_across_reshard_events() {
        let make = || cluster(2, 0.020, tp_cluster_cfg());
        let mut workload: Vec<Request> = (0..14)
            .map(|i| Request::new(i as u64, vec![1; 16], 24, 0.0))
            .collect();
        workload.extend(
            (0..6).map(|i| Request::new(100 + i as u64, vec![1; 16], 8, 0.01 + 0.1 * i as f64)),
        );
        let a = make().run(workload.clone()).unwrap();
        let b = make().run_lockstep(workload).unwrap();
        assert!(a.aggregate.reshards >= 1, "scenario must actually reshard");
        let ids = |r: &ClusterReport| -> Vec<u64> { r.completions.iter().map(|c| c.id).collect() };
        assert_eq!(ids(&a), ids(&b));
        let timeline_bits = |r: &ClusterReport| -> Vec<(u64, usize, usize)> {
            r.reshard_timeline
                .iter()
                .map(|&(t, i, tp)| (t.to_bits(), i, tp))
                .collect()
        };
        assert_eq!(timeline_bits(&a), timeline_bits(&b));
        // dispatch counters agree (heap lazy deletions excepted)
        assert_eq!(a.events.arrival_events, b.events.arrival_events);
        assert_eq!(a.events.control_events, b.events.control_events);
        assert_eq!(a.events.replica_step_events, b.events.replica_step_events);
        assert_eq!(a.events.reshard_events, b.events.reshard_events);
        assert_eq!(a.aggregate.reshards, b.aggregate.reshards);
        assert_eq!(
            a.aggregate.reshard_repartition_s.to_bits(),
            b.aggregate.reshard_repartition_s.to_bits()
        );
        assert_eq!(a.control_tick_count, b.control_tick_count);
        for (x, y) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(x.iterations, y.iterations);
            assert_eq!(x.final_tp_degree, y.final_tp_degree);
        }
    }

    /// Satellite: `record_control_ticks: false` keeps only the count and
    /// a bounded head/tail window, and those must agree exactly with the
    /// full vector a recording run produces.
    #[test]
    fn control_tick_recording_can_be_bounded() {
        let run_with = |record: bool| {
            let cfg = ClusterConfig {
                policy: RoutingPolicy::RoundRobin,
                engine: sim_engine_cfg(PrecisionPolicy::Fp16Only),
                surge: SurgeConfig::disabled(),
                autopilot: Some(AutopilotConfig::default()),
                record_control_ticks: record,
                ..ClusterConfig::default()
            };
            let mut c = cluster(2, 0.020, cfg);
            // long decode tail -> well over 16 control ticks
            let reqs: Vec<Request> = (0..8)
                .map(|i| Request::new(i as u64, vec![1; 16], 160, 0.0))
                .collect();
            c.run(reqs).unwrap()
        };
        let full = run_with(true);
        assert_eq!(full.control_ticks.len(), full.control_tick_count);
        assert!(
            full.control_tick_count > 32,
            "scenario too short to exercise the bound: {}",
            full.control_tick_count
        );
        assert_eq!(full.control_ticks_head, full.control_ticks[..16]);
        assert_eq!(
            full.control_ticks_tail,
            full.control_ticks[full.control_tick_count - 16..]
        );

        let bounded = run_with(false);
        assert!(bounded.control_ticks.is_empty(), "bounded run kept the vec");
        assert_eq!(bounded.control_tick_count, full.control_tick_count);
        assert_eq!(bounded.control_ticks_head, full.control_ticks_head);
        assert_eq!(bounded.control_ticks_tail, full.control_ticks_tail);
    }
}

//! Iteration-level scheduler: continuous batching with chunked prefill.
//!
//! Each engine iteration executes either one prefill chunk (admission /
//! TTFT path) or one decode batch (TPOT path). Prefill takes priority
//! while KV slots and blocks are available — the vLLM default — and the
//! decode batch is everything currently in the Decoding state, capped by
//! the largest AOT decode bucket (round-robin beyond the cap).

use super::kv::KvCacheManager;
use super::request::{Request, RequestId, RequestState};

/// What the engine should run this iteration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum IterationPlan {
    /// Prefill `chunk` tokens of request `id` starting at its current
    /// prefill offset.
    Prefill { id: RequestId, chunk: usize },
    /// Decode one token for each listed request.
    Decode { ids: Vec<RequestId> },
    /// Nothing runnable (queue empty or blocked on KV space).
    Idle,
}

/// Scheduler bookkeeping over the request table.
pub struct Scheduler {
    /// Available prefill chunk sizes (ascending).
    pub prefill_chunks: Vec<usize>,
    /// Maximum decode batch (largest AOT bucket, or sim batch cap).
    pub max_decode_batch: usize,
    /// Round-robin cursor for oversubscribed decode.
    rr_cursor: usize,
}

impl Scheduler {
    pub fn new(mut prefill_chunks: Vec<usize>, max_decode_batch: usize) -> Scheduler {
        assert!(!prefill_chunks.is_empty());
        assert!(max_decode_batch > 0);
        prefill_chunks.sort_unstable();
        Scheduler {
            prefill_chunks,
            max_decode_batch,
            rr_cursor: 0,
        }
    }

    /// Largest chunk size <= remaining, or the smallest chunk (remaining
    /// is then padded upstream — callers guarantee prompt lengths are
    /// multiples of the smallest chunk).
    pub fn chunk_for(&self, remaining: usize) -> usize {
        self.prefill_chunks
            .iter()
            .rev()
            .copied()
            .find(|&c| c <= remaining)
            .unwrap_or(self.prefill_chunks[0])
    }

    /// Decide the next iteration's work.
    ///
    /// `requests` is the full table; the scheduler inspects states.
    pub fn plan(&mut self, requests: &[Request], kv: &KvCacheManager) -> IterationPlan {
        self.plan_inner(requests, kv, true)
    }

    /// [`Scheduler::plan`] with admission disabled — the reshard drain
    /// mode: in-flight prefills continue and decodes run, but queued
    /// requests stay queued until the replica resumes.
    pub fn plan_frozen(&mut self, requests: &[Request], kv: &KvCacheManager) -> IterationPlan {
        self.plan_inner(requests, kv, false)
    }

    fn plan_inner(
        &mut self,
        requests: &[Request],
        kv: &KvCacheManager,
        admit: bool,
    ) -> IterationPlan {
        // 1. continue a prefill already in flight (holds a slot)
        if let Some(r) = requests
            .iter()
            .find(|r| r.state == RequestState::Prefilling && r.remaining_prompt() > 0)
        {
            return IterationPlan::Prefill {
                id: r.id,
                chunk: self.chunk_for(r.remaining_prompt()),
            };
        }

        // 2. admit a queued request if the block budget allows. The
        // reservation length comes from the cache's admission mode:
        // conservative full-context (Reserve) or prompt-only paging
        // (Paged, where decode growth is backed by demotion and
        // preempt-by-offload). Admission is gated by real free-block
        // counts alone — there is no slot cap. Skipped entirely while a
        // reshard drain has admission frozen.
        if admit {
            if let Some(r) = requests
                .iter()
                .filter(|r| r.state == RequestState::Queued)
                .min_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap())
            {
                if kv.can_admit(kv.admit_len(r.prompt.len(), r.max_new_tokens)) {
                    return IterationPlan::Prefill {
                        id: r.id,
                        chunk: self.chunk_for(r.prompt.len()),
                    };
                }
            }
        }

        // 3. decode everything running (round-robin window if over cap).
        // The decode set is tier-agnostic: host-piggybacked sequences
        // (`HostDecoding`) batch together with device-resident ones —
        // the engine partitions the batch by tier when it runs it. The
        // state only exists with piggybacking enabled, so disabled runs
        // plan byte-identically to the pre-piggyback scheduler.
        let decoding: Vec<RequestId> = requests
            .iter()
            .filter(|r| {
                matches!(
                    r.state,
                    RequestState::Decoding | RequestState::HostDecoding
                )
            })
            .map(|r| r.id)
            .collect();
        if decoding.is_empty() {
            return IterationPlan::Idle;
        }
        if decoding.len() <= self.max_decode_batch {
            return IterationPlan::Decode { ids: decoding };
        }
        let n = decoding.len();
        let start = self.rr_cursor % n;
        let ids: Vec<RequestId> = (0..self.max_decode_batch)
            .map(|i| decoding[(start + i) % n])
            .collect();
        self.rr_cursor = (start + self.max_decode_batch) % n;
        IterationPlan::Decode { ids }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv::{KvCacheManager, KvGeometry, KvPressureConfig};

    fn kv(blocks: usize) -> KvCacheManager {
        KvCacheManager::accounting_only(
            KvGeometry {
                n_layers: 1,
                n_heads: 1,
                max_seq: 128,
                head_dim: 1,
                block_size: 16,
                total_blocks: blocks,
            },
            KvPressureConfig::default(),
        )
    }

    fn req(id: u64, state: RequestState, prompt_len: usize, arrival: f64) -> Request {
        let mut r = Request::new(id, vec![1; prompt_len], 16, arrival);
        r.state = state;
        r
    }

    #[test]
    fn prefill_priority_over_decode() {
        let mut s = Scheduler::new(vec![8, 16, 32], 8);
        let kv = kv(64);
        let requests = vec![
            req(1, RequestState::Decoding, 16, 0.0),
            req(2, RequestState::Queued, 16, 0.1),
        ];
        assert_eq!(
            s.plan(&requests, &kv),
            IterationPlan::Prefill { id: 2, chunk: 16 }
        );
    }

    #[test]
    fn inflight_prefill_continues_first() {
        let mut s = Scheduler::new(vec![8, 16, 32], 8);
        let kv = kv(64);
        let mut r1 = req(1, RequestState::Prefilling, 48, 0.0);
        r1.prefilled = 32;
        let requests = vec![r1, req(2, RequestState::Queued, 16, 0.1)];
        assert_eq!(
            s.plan(&requests, &kv),
            IterationPlan::Prefill { id: 1, chunk: 16 }
        );
    }

    #[test]
    fn fcfs_admission() {
        let mut s = Scheduler::new(vec![8], 8);
        let kv = kv(64);
        let requests = vec![
            req(2, RequestState::Queued, 8, 0.2),
            req(1, RequestState::Queued, 8, 0.1),
        ];
        assert_eq!(
            s.plan(&requests, &kv),
            IterationPlan::Prefill { id: 1, chunk: 8 }
        );
    }

    #[test]
    fn decode_when_kv_full() {
        let mut s = Scheduler::new(vec![8], 8);
        let mut k = kv(3);
        let _seq = k.allocate(32).unwrap(); // 2+1 blocks: exhausts the budget
        let requests = vec![
            req(1, RequestState::Decoding, 8, 0.0),
            req(2, RequestState::Queued, 8, 0.1),
        ];
        assert_eq!(
            s.plan(&requests, &k),
            IterationPlan::Decode { ids: vec![1] }
        );
    }

    #[test]
    fn offloaded_requests_are_not_decoded() {
        let mut s = Scheduler::new(vec![8], 8);
        let k = kv(64);
        let requests = vec![
            req(1, RequestState::Decoding, 8, 0.0),
            req(2, RequestState::Offloaded, 8, 0.1),
        ];
        assert_eq!(
            s.plan(&requests, &k),
            IterationPlan::Decode { ids: vec![1] },
            "host-resident sequences must wait for their fetch"
        );
    }

    #[test]
    fn host_decoding_requests_join_the_decode_batch() {
        let mut s = Scheduler::new(vec![8], 8);
        let k = kv(64);
        let requests = vec![
            req(1, RequestState::Decoding, 8, 0.0),
            req(2, RequestState::HostDecoding, 8, 0.1),
            req(3, RequestState::Offloaded, 8, 0.2),
        ];
        assert_eq!(
            s.plan(&requests, &k),
            IterationPlan::Decode { ids: vec![1, 2] },
            "piggybacked lanes decode; plain offloaded ones still wait"
        );
    }

    #[test]
    fn decode_round_robin_over_cap() {
        let mut s = Scheduler::new(vec![8], 2);
        let kv = kv(640);
        let requests: Vec<Request> = (0..5)
            .map(|i| req(i, RequestState::Decoding, 8, i as f64))
            .collect();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..5 {
            if let IterationPlan::Decode { ids } = s.plan(&requests, &kv) {
                assert_eq!(ids.len(), 2);
                seen.extend(ids);
            } else {
                panic!("expected decode");
            }
        }
        // all five sequences get scheduled within a few rounds
        assert_eq!(seen.len(), 5);
    }

    #[test]
    fn frozen_plan_never_admits_but_keeps_inflight_work() {
        let mut s = Scheduler::new(vec![8, 16, 32], 8);
        let k = kv(64);
        // a queued request alone: frozen plan idles instead of admitting
        let queued = vec![req(2, RequestState::Queued, 16, 0.1)];
        assert_eq!(s.plan_frozen(&queued, &k), IterationPlan::Idle);
        // in-flight prefill still continues under freeze
        let mut r1 = req(1, RequestState::Prefilling, 48, 0.0);
        r1.prefilled = 32;
        let requests = vec![r1, req(2, RequestState::Queued, 16, 0.1)];
        assert_eq!(
            s.plan_frozen(&requests, &k),
            IterationPlan::Prefill { id: 1, chunk: 16 }
        );
        // and decodes keep running while the queue waits
        let requests = vec![
            req(1, RequestState::Decoding, 8, 0.0),
            req(2, RequestState::Queued, 16, 0.1),
        ];
        assert_eq!(
            s.plan_frozen(&requests, &k),
            IterationPlan::Decode { ids: vec![1] }
        );
    }

    #[test]
    fn idle_when_nothing_runnable() {
        let mut s = Scheduler::new(vec![8], 2);
        let kv = kv(64);
        assert_eq!(s.plan(&[], &kv), IterationPlan::Idle);
        let requests = vec![req(1, RequestState::Finished, 8, 0.0)];
        assert_eq!(s.plan(&requests, &kv), IterationPlan::Idle);
    }

    #[test]
    fn chunk_selection() {
        let s = Scheduler::new(vec![8, 16, 32], 8);
        assert_eq!(s.chunk_for(100), 32);
        assert_eq!(s.chunk_for(24), 16);
        assert_eq!(s.chunk_for(8), 8);
        assert_eq!(s.chunk_for(3), 8); // padded upstream
    }
}

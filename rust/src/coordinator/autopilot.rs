//! The closed-loop SLO autopilot — dynamic precision as a real control
//! system, not a per-engine heuristic.
//!
//! The paper's headline claim is what NestedFP *enables*: "a flexible
//! platform for dynamic, SLO-aware precision selection" under bursty
//! load (§1, §3.2). PR 1 approximated that with a reactive queue-depth
//! trigger (`ClusterRouter::update_escalation`); this module replaces it
//! with the controller that MorphServe-style systems show is where
//! goodput is actually won or lost:
//!
//! 1. **Sliding-window SLO tracking** ([`SloTracker`]) — per replica,
//!    online TTFT/TPOT p50/p99 over the last `window_s` virtual-clock
//!    seconds, compared against [`SloConfig`] targets.
//! 2. **Per-replica hysteresis state machines** — each replica walks the
//!    three-rung ladder FP16 → Mixed → FP8 ([`PrecisionDirective`]) one
//!    rung at a time, with separate escalate/promote dwell times on the
//!    virtual clock and a post-promotion cooldown so the fleet cannot
//!    thrash.
//! 3. **A cluster escalation ladder** — one damped severity integrator
//!    (±1 rung per control tick) distributes FP8 rungs to the *fewest*
//!    replicas needed, ordered by SLO headroom (the router's own
//!    [`slo_headroom`] score breaks ties), and hands them back in the
//!    reverse order as the surge drains.
//! 4. **A surge predictor** ([`SurgePredictor`]) — fast/slow EWMAs over
//!    the observed arrival-rate series (the `trace::azure` shape);
//!    a rising short-horizon slope *pre-escalates* the fleet to `Mixed`
//!    before the queue backs up, and the pinned-FP8 rungs are reserved
//!    for measured (not predicted) pressure.
//! 5. **A second, parallelism ladder** — per-replica tensor-parallel
//!    targets over the shard layer's rungs (powers of two up to
//!    [`AutopilotConfig::max_tp`]), with its own much longer dwell times
//!    because a TP move costs a drain → repartition → resume window
//!    ([`crate::shard::Resharder`]) rather than a kernel switch. The two
//!    ladders are arbitrated: the cheap knob (precision) moves first, TP
//!    escalates only once a replica's precision rung is saturated and
//!    measured pressure persists, TP releases only after precision has
//!    fully recovered to FP16, and a replica never moves both knobs in
//!    the same control tick.
//!
//! The autopilot only *directs*; the per-engine
//! [`PrecisionController`](super::precision::PrecisionController) still
//! owns the iteration-level decision whenever its rung is `Mixed`, and
//! the cluster's resharder reconciles actual backend TP degrees toward
//! the ladder's targets.

use std::collections::VecDeque;

use super::engine::EngineStep;
use super::precision::{PrecisionDirective, SloConfig};
use super::router::{slo_headroom, ReplicaSnapshot};

/// Autopilot tuning. Defaults mirror the per-engine controller's
/// high/low water marks (0.85 / 0.60) so the two control layers agree on
/// what "pressured" means.
#[derive(Clone, Copy, Debug)]
pub struct AutopilotConfig {
    /// SLO targets the tracker scores against.
    pub slo: SloConfig,
    /// Sliding SLO window, virtual-clock seconds.
    pub window_s: f64,
    /// Minimum spacing between control decisions.
    pub control_interval_s: f64,
    /// Escalate one severity rung when cluster pressure exceeds this.
    pub up_pressure: f64,
    /// Release one severity rung when cluster pressure falls below this.
    pub down_pressure: f64,
    /// Queue depth that alone saturates a replica's pressure score to 1.
    pub queue_ref: f64,
    /// Minimum time in a rung before escalating (toward FP8).
    pub escalate_dwell_s: f64,
    /// Minimum time in a rung before promoting (toward FP16).
    pub promote_dwell_s: f64,
    /// After a promotion, no re-escalation of that replica for this long.
    pub cooldown_s: f64,
    /// Pressure bonus that keeps an already-demoted replica demoted in
    /// the ladder ordering (assignment stickiness against churn).
    pub sticky_bonus: f64,
    /// Predictor boost at full relative slope (0 disables pre-escalation).
    pub predictor_gain: f64,
    /// Rate floor for the predictor's relative-slope normalization, req/s
    /// (prevents divide-by-tiny on idle fleets).
    pub predictor_floor_rate: f64,
    /// Highest precision rung the ladder may assign: 0 pins FP16 (the
    /// bench's parallelism-only arm), 1 caps at Mixed, 2 (default)
    /// allows the full FP16 → Mixed → FP8 walk.
    pub max_precision_rung: usize,
    /// Per-layer morphing ladder resolution. 0 (default) keeps the
    /// legacy three-rung whole-replica ladder, bit for bit. `R >= 2`
    /// walks `R + 1` fine positions per replica (0 = FP16, `R` = FP8,
    /// interior = partial layer schedules) under the same macro-scale
    /// dwell law: escalation jumps `R/2` rungs per allowed move and
    /// promotion walks one rung at `2/R` of the promote dwell, so
    /// endpoint-to-endpoint timing matches the coarse arm while the
    /// interior gains resolution. Engines consume the fine rung through
    /// [`PrecisionController::apply_layer_rung`](super::precision::PrecisionController::apply_layer_rung).
    pub morph_rungs: usize,
    /// Highest tensor-parallel degree the parallelism ladder may target
    /// (power of two). 1 disables the second ladder entirely — the
    /// pre-shard-layer behavior, bit for bit.
    pub max_tp: usize,
    /// Minimum time at a TP degree before escalating (more shards).
    pub tp_escalate_dwell_s: f64,
    /// Minimum time at a TP degree before releasing (fewer shards).
    pub tp_promote_dwell_s: f64,
    /// After a TP release, no TP re-escalation of that replica for this
    /// long.
    pub tp_cooldown_s: f64,
}

impl Default for AutopilotConfig {
    fn default() -> Self {
        AutopilotConfig {
            slo: SloConfig::default(),
            window_s: 8.0,
            control_interval_s: 0.25,
            up_pressure: 0.85,
            down_pressure: 0.60,
            queue_ref: 6.0,
            escalate_dwell_s: 0.5,
            promote_dwell_s: 2.0,
            cooldown_s: 1.5,
            sticky_bonus: 0.15,
            predictor_gain: 0.6,
            predictor_floor_rate: 1.0,
            max_precision_rung: 2,
            morph_rungs: 0,
            max_tp: 1,
            // a reshard bills a full drain + weight-move window, so the
            // parallelism ladder dwells an order of magnitude longer
            // than the precision ladder before touching the knob again
            tp_escalate_dwell_s: 2.0,
            tp_promote_dwell_s: 6.0,
            tp_cooldown_s: 4.0,
        }
    }
}

/// Per-replica sliding-window latency tracker: online TTFT/TPOT
/// percentiles over the last `window_s` seconds of the virtual clock.
#[derive(Clone, Debug, Default)]
pub struct SloTracker {
    /// (observation time, TTFT seconds) of completions in the window.
    ttft: VecDeque<(f64, f64)>,
    /// (observation time, worst decode gap seconds) per decode iteration.
    tpot: VecDeque<(f64, f64)>,
}

/// Exact percentile over an unsorted sample list (`None` when empty) —
/// delegates to the crate's single percentile definition,
/// [`crate::util::stats::percentile_sorted`], so the control loop and
/// the reported metrics can never disagree about what a p99 is.
///
/// NaN samples sort last and are dropped (counted in the global
/// telemetry registry under `autopilot.nan_dropped`) — one poisoned
/// latency observation must degrade one data point, never panic the
/// control loop.
fn percentile_of(mut xs: Vec<f64>, q: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let dropped = crate::util::stats::sort_drop_nans(&mut xs);
    if dropped > 0 {
        crate::telemetry::registry::with_global(|r| {
            r.add_int("autopilot.nan_dropped", dropped as u64)
        });
    }
    if xs.is_empty() {
        return None;
    }
    Some(crate::util::stats::percentile_sorted(&xs, q))
}

impl SloTracker {
    pub fn observe_ttft(&mut self, t: f64, ttft_s: f64) {
        self.ttft.push_back((t, ttft_s));
    }

    pub fn observe_tpot(&mut self, t: f64, gap_s: f64) {
        self.tpot.push_back((t, gap_s));
    }

    /// Drop samples older than `window_s` before `now`.
    pub fn evict(&mut self, now: f64, window_s: f64) {
        let cutoff = now - window_s;
        while self.ttft.front().is_some_and(|&(t, _)| t < cutoff) {
            self.ttft.pop_front();
        }
        while self.tpot.front().is_some_and(|&(t, _)| t < cutoff) {
            self.tpot.pop_front();
        }
    }

    /// Windowed TTFT percentile, `q` in [0, 100]; `None` when no
    /// completion landed inside the window.
    pub fn ttft_percentile(&self, q: f64) -> Option<f64> {
        percentile_of(self.ttft.iter().map(|&(_, v)| v).collect(), q)
    }

    /// Windowed TPOT percentile over per-iteration worst gaps.
    pub fn tpot_percentile(&self, q: f64) -> Option<f64> {
        percentile_of(self.tpot.iter().map(|&(_, v)| v).collect(), q)
    }

    pub fn samples(&self) -> (usize, usize) {
        (self.ttft.len(), self.tpot.len())
    }
}

/// Short-horizon arrival-rate trend over fast/slow EWMAs of the observed
/// per-second arrival counts (the `trace::azure` rate-series shape,
/// reconstructed online from routed arrivals — no lookahead).
#[derive(Clone, Debug)]
pub struct SurgePredictor {
    bucket_s: f64,
    tau_fast: f64,
    tau_slow: f64,
    bucket_start: f64,
    count: f64,
    fast: f64,
    slow: f64,
    primed: bool,
}

impl Default for SurgePredictor {
    fn default() -> Self {
        SurgePredictor {
            bucket_s: 1.0,
            tau_fast: 2.0,
            tau_slow: 8.0,
            bucket_start: 0.0,
            count: 0.0,
            fast: 0.0,
            slow: 0.0,
            primed: false,
        }
    }
}

impl SurgePredictor {
    /// Close every whole bucket up to `t`, feeding its rate into the
    /// EWMAs (empty buckets feed zeros — decay is part of the signal).
    /// Idempotent for a fixed `t`, so the event core's bucket clock can
    /// call it on exact boundaries without perturbing the signal.
    pub fn roll_to(&mut self, t: f64) {
        while t >= self.bucket_start + self.bucket_s {
            let rate = self.count / self.bucket_s;
            if self.primed {
                let af = 1.0 - (-self.bucket_s / self.tau_fast).exp();
                let sl = 1.0 - (-self.bucket_s / self.tau_slow).exp();
                self.fast += af * (rate - self.fast);
                self.slow += sl * (rate - self.slow);
            } else {
                self.fast = rate;
                self.slow = rate;
                self.primed = true;
            }
            self.count = 0.0;
            self.bucket_start += self.bucket_s;
        }
    }

    /// Record one arrival at time `t` (non-decreasing across calls).
    pub fn observe_arrival(&mut self, t: f64) {
        self.roll_to(t);
        if t >= self.bucket_start {
            self.count += 1.0;
        }
    }

    /// Smoothed arrival rates `(fast, slow)`, req/s.
    pub fn rates(&self) -> (f64, f64) {
        (self.fast, self.slow)
    }

    /// The next bucket boundary: the earliest time at which
    /// [`SurgePredictor::roll_to`] would close another bucket. Exact f64
    /// integers for the default 1 s buckets, so an event scheduled here
    /// lands on the boundary bit-for-bit.
    pub fn next_boundary(&self) -> f64 {
        self.bucket_start + self.bucket_s
    }

    /// The first bucket boundary strictly after `t` (used to seed the
    /// event core's predictor clock at the first arrival).
    pub fn boundary_after(&self, t: f64) -> f64 {
        let k = ((t - self.bucket_start) / self.bucket_s).floor().max(0.0) + 1.0;
        self.bucket_start + k * self.bucket_s
    }

    /// Pressure boost in `[0, gain]`: positive only while the fast EWMA
    /// runs ahead of the slow one (a building surge), scaled by the
    /// relative slope so a 2x ramp saturates it and steady load (fast ==
    /// slow) contributes nothing.
    pub fn boost(&mut self, now: f64, gain: f64, floor_rate: f64) -> f64 {
        self.roll_to(now);
        if gain <= 0.0 {
            return 0.0;
        }
        let rel = (self.fast - self.slow) / self.slow.max(floor_rate);
        gain * rel.clamp(0.0, 1.0)
    }
}

/// Per-replica directive dwell/switch accounting (mirrored into
/// [`Metrics`](super::metrics::Metrics) and merged across replicas).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ModeStats {
    /// Virtual-clock seconds spent under each directive, indexed by
    /// [`PrecisionDirective::rung`]: `[fp16, mixed, fp8]`.
    pub dwell_s: [f64; 3],
    /// Directive transitions (each is one rung: FP16↔Mixed or Mixed↔FP8).
    pub switches: usize,
}

/// The per-replica hysteresis state machine. It receives an *assigned*
/// rung from the cluster ladder every control tick and walks toward it,
/// subject to dwell times and the post-promotion cooldown — the
/// assignment can flap, the replica cannot.
///
/// The ladder has `max_rung + 1` positions: 0 is FP16, `max_rung` is
/// FP8, everything in between maps to the `Mixed` directive (and, under
/// per-layer morphing, to a partial
/// [`LayerSchedule`](super::precision::LayerSchedule) demotion — see
/// [`super::precision::PrecisionController::apply_layer_rung`]). With
/// `max_rung == 2` this is exactly the legacy coarse FSM: one rung per
/// move, one directive per rung, same dwell gates, same timeline.
#[derive(Clone, Debug)]
struct ReplicaFsm {
    /// Fine ladder position in `0..=max_rung`.
    state: usize,
    /// Top rung of this replica's ladder (2 = legacy coarse ladder).
    max_rung: usize,
    entered_at: f64,
    last_promote_at: f64,
    last_tick: f64,
    stats: ModeStats,
    /// Coarse directive change points (pushed only when the mapped
    /// directive changes — identical to the legacy timeline at R = 2).
    timeline: Vec<(f64, PrecisionDirective)>,
    /// Fine rung change points (every FSM move).
    rung_timeline: Vec<(f64, usize)>,
    /// Virtual-clock seconds per fine rung, `[0 ..= max_rung]`.
    rung_dwell: Vec<f64>,
}

impl ReplicaFsm {
    fn new(max_rung: usize) -> ReplicaFsm {
        assert!(max_rung >= 2, "the ladder needs at least 3 positions");
        ReplicaFsm {
            // boot state: "has been FP16 forever" — the first escalation
            // is never dwell-blocked by an arbitrary t=0 entry stamp
            state: 0,
            max_rung,
            entered_at: f64::NEG_INFINITY,
            last_promote_at: f64::NEG_INFINITY,
            last_tick: 0.0,
            stats: ModeStats::default(),
            timeline: Vec::new(),
            rung_timeline: Vec::new(),
            rung_dwell: vec![0.0; max_rung + 1],
        }
    }

    /// Map a fine rung to the coarse three-rung directive.
    fn directive_of(rung: usize, max_rung: usize) -> PrecisionDirective {
        if rung == 0 {
            PrecisionDirective::Fp16
        } else if rung >= max_rung {
            PrecisionDirective::Fp8
        } else {
            PrecisionDirective::Mixed
        }
    }

    fn directive(&self) -> PrecisionDirective {
        Self::directive_of(self.state, self.max_rung)
    }

    /// Per-move promotion dwell: the fine ladder walks back one rung at
    /// a time, so the per-rung dwell is scaled to `2/R` of the coarse
    /// value — a full FP8 → FP16 drain takes exactly as long as the
    /// coarse ladder's two-rung walk. `max_rung == 2` uses the config
    /// value untouched (legacy, bit for bit).
    fn promote_dwell(&self, cfg: &AutopilotConfig) -> f64 {
        if self.max_rung == 2 {
            cfg.promote_dwell_s
        } else {
            cfg.promote_dwell_s * 2.0 / self.max_rung as f64
        }
    }

    fn tick(&mut self, now: f64, target: usize, cfg: &AutopilotConfig) -> PrecisionDirective {
        let dt = (now - self.last_tick).max(0.0);
        self.stats.dwell_s[self.directive().rung()] += dt;
        self.rung_dwell[self.state] += dt;
        self.last_tick = self.last_tick.max(now);
        let target = target.min(self.max_rung);
        if target != self.state {
            let escalating = target > self.state;
            let in_state = now - self.entered_at;
            let allowed = if escalating {
                in_state >= cfg.escalate_dwell_s && now - self.last_promote_at >= cfg.cooldown_s
            } else {
                in_state >= self.promote_dwell(cfg)
            };
            if allowed {
                let before = self.directive();
                if escalating {
                    // escalation jumps R/2 rungs per allowed move, so the
                    // coarse-directive timing (FP16 -> Mixed -> FP8 in two
                    // dwell-gated moves) is preserved at every resolution
                    let step = (self.max_rung / 2).max(1);
                    self.state = (self.state + step).min(target);
                } else {
                    self.state -= 1;
                    self.last_promote_at = now;
                }
                self.entered_at = now;
                self.rung_timeline.push((now, self.state));
                let after = self.directive();
                if after != before {
                    self.stats.switches += 1;
                    self.timeline.push((now, after));
                }
            }
        }
        self.directive()
    }
}

/// The parallelism ladder's per-replica state machine: the desired
/// tensor-parallel degree, walked one power-of-two rung at a time under
/// its own (much longer) dwell discipline. This is a *target* — the
/// cluster's resharder reconciles the actual backend degree toward it
/// through drain → repartition → resume windows, so the FSM never
/// assumes a move is instantaneous.
#[derive(Clone, Debug)]
struct TpFsm {
    tp: usize,
    entered_at: f64,
    last_release_at: f64,
    switches: usize,
    timeline: Vec<(f64, usize)>,
}

impl TpFsm {
    fn new() -> TpFsm {
        TpFsm {
            // boot state mirrors ReplicaFsm: "has been tp=1 forever"
            tp: 1,
            entered_at: f64::NEG_INFINITY,
            last_release_at: f64::NEG_INFINITY,
            switches: 0,
            timeline: Vec::new(),
        }
    }

    fn step_to(&mut self, now: f64, tp: usize, released: bool) {
        self.tp = tp;
        self.entered_at = now;
        if released {
            self.last_release_at = now;
        }
        self.switches += 1;
        self.timeline.push((now, tp));
    }
}

/// The cluster-level closed-loop controller. Owned by
/// [`ClusterRouter`](super::cluster::ClusterRouter) when
/// [`ClusterConfig::autopilot`](super::cluster::ClusterConfig) is set;
/// also drivable standalone (property tests, the live TCP server's
/// wall-clock monitor) through [`Autopilot::control_at`].
pub struct Autopilot {
    cfg: AutopilotConfig,
    /// Top fine rung per replica: 2 in legacy coarse mode
    /// (`morph_rungs == 0`), else `max(2, morph_rungs)`.
    rungs: usize,
    trackers: Vec<SloTracker>,
    fsms: Vec<ReplicaFsm>,
    tp_fsms: Vec<TpFsm>,
    predictor: SurgePredictor,
    /// Cluster ladder position: total demotion rungs distributed over the
    /// fleet, in `0..=R * n_replicas` (0 = all FP16, Rn = all FP8; the
    /// legacy coarse ladder has R = 2).
    severity: usize,
    last_control: f64,
    /// Severity changes driven by the predictor alone (measured pressure
    /// was still below the escalation threshold) — the "pre-escalations"
    /// the surge bench reports.
    pub pre_escalations: usize,
    /// (time, severity) change points of the cluster ladder.
    pub ladder_timeline: Vec<(f64, usize)>,
}

impl Autopilot {
    pub fn new(n_replicas: usize, cfg: AutopilotConfig) -> Autopilot {
        assert!(n_replicas > 0, "autopilot needs at least one replica");
        assert!(
            cfg.max_tp >= 1 && cfg.max_tp.is_power_of_two(),
            "max_tp must be a power of two, got {}",
            cfg.max_tp
        );
        assert!(cfg.max_precision_rung <= 2, "precision rungs are 0..=2");
        let rungs = if cfg.morph_rungs == 0 {
            2
        } else {
            cfg.morph_rungs.max(2)
        };
        Autopilot {
            cfg,
            rungs,
            trackers: vec![SloTracker::default(); n_replicas],
            fsms: (0..n_replicas).map(|_| ReplicaFsm::new(rungs)).collect(),
            tp_fsms: (0..n_replicas).map(|_| TpFsm::new()).collect(),
            predictor: SurgePredictor::default(),
            severity: 0,
            last_control: f64::NEG_INFINITY,
            pre_escalations: 0,
            ladder_timeline: Vec::new(),
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.fsms.len()
    }

    pub fn config(&self) -> &AutopilotConfig {
        &self.cfg
    }

    /// Current ladder severity (see [`Autopilot::control_at`]).
    pub fn severity(&self) -> usize {
        self.severity
    }

    /// Current per-replica directives.
    pub fn directives(&self) -> Vec<PrecisionDirective> {
        self.fsms.iter().map(|f| f.directive()).collect()
    }

    /// One replica's directive change points `(time, new directive)`.
    pub fn directive_timeline(&self, i: usize) -> &[(f64, PrecisionDirective)] {
        &self.fsms[i].timeline
    }

    /// Per-replica fine rungs under per-layer morphing: `None` in legacy
    /// coarse mode (`morph_rungs == 0`), else `(states, max_rung)` where
    /// each state is in `0..=max_rung`. The cluster driver feeds these to
    /// [`PrecisionController::apply_layer_rung`](super::precision::PrecisionController::apply_layer_rung).
    pub fn fine_rungs(&self) -> Option<(Vec<usize>, usize)> {
        if self.cfg.morph_rungs == 0 {
            return None;
        }
        Some((self.fsms.iter().map(|f| f.state).collect(), self.rungs))
    }

    /// One replica's fine-rung change points `(time, new rung)` — every
    /// FSM move, including the interior steps the coarse
    /// [`Autopilot::directive_timeline`] collapses.
    pub fn rung_timeline(&self, i: usize) -> &[(f64, usize)] {
        &self.fsms[i].rung_timeline
    }

    /// One replica's virtual-clock seconds per fine rung.
    pub fn rung_dwell(&self, i: usize) -> &[f64] {
        &self.fsms[i].rung_dwell
    }

    /// Current per-replica tensor-parallel *targets* — the parallelism
    /// ladder's desired degrees. The cluster's resharder reconciles the
    /// actual backend degrees toward these through clock-billed windows.
    pub fn tp_targets(&self) -> Vec<usize> {
        self.tp_fsms.iter().map(|f| f.tp).collect()
    }

    /// One replica's TP-target change points `(time, new tp)`.
    pub fn tp_timeline(&self, i: usize) -> &[(f64, usize)] {
        &self.tp_fsms[i].timeline
    }

    /// Total parallelism-ladder moves across the fleet.
    pub fn tp_switches(&self) -> usize {
        self.tp_fsms.iter().map(|f| f.switches).sum()
    }

    /// One replica's dwell/switch accounting (call [`Autopilot::finish`]
    /// first to bill the trailing dwell).
    pub fn mode_stats(&self, i: usize) -> ModeStats {
        self.fsms[i].stats
    }

    /// One replica's sliding-window tracker (read-only).
    pub fn tracker(&self, i: usize) -> &SloTracker {
        &self.trackers[i]
    }

    /// Whether a control tick is due at `now`. Cheap — callers on hot
    /// driver loops should gate snapshot construction on this before
    /// paying for [`Autopilot::maybe_control`]'s inputs.
    pub fn due(&self, now: f64) -> bool {
        now - self.last_control >= self.cfg.control_interval_s
    }

    /// Feed the predictor one routed arrival (time non-decreasing).
    pub fn observe_arrival(&mut self, t: f64) {
        self.predictor.observe_arrival(t);
    }

    /// Feed one replica's engine-step outcome into its tracker.
    pub fn observe_step(&mut self, i: usize, now: f64, step: &EngineStep) {
        if let Some(gap) = step.tpot_worst {
            self.trackers[i].observe_tpot(now, gap);
        }
        for c in &step.completions {
            self.trackers[i].observe_ttft(now, c.ttft_s);
        }
    }

    /// One replica's pressure score: max of the windowed p99-vs-target
    /// ratios and the normalized queue depth. 1.0 ≈ "at the SLO edge".
    pub fn replica_pressure(&mut self, now: f64, i: usize, snap: &ReplicaSnapshot) -> f64 {
        self.trackers[i].evict(now, self.cfg.window_s);
        let tp = self.trackers[i].tpot_percentile(99.0).unwrap_or(0.0) / self.cfg.slo.tpot_target;
        let tt = self.trackers[i].ttft_percentile(99.0).unwrap_or(0.0) / self.cfg.slo.ttft_target;
        let q = snap.queued_requests as f64 / self.cfg.queue_ref;
        tp.max(tt).max(q)
    }

    /// Run one control decision if the control interval elapsed:
    /// pressures from the trackers + snapshots, predictor boost, then
    /// [`Autopilot::control_at`]. Returns the directives to apply.
    ///
    /// Wall-clock callers (the live server monitor) use this `due()`
    /// gate; the discrete-event cluster driver schedules control ticks
    /// itself and calls [`Autopilot::control_with_snapshots`] directly —
    /// its schedule *is* the cadence, and re-gating on float arithmetic
    /// here would skip exactly-on-time ticks to rounding.
    pub fn maybe_control(
        &mut self,
        now: f64,
        snaps: &[ReplicaSnapshot],
    ) -> Option<Vec<PrecisionDirective>> {
        if !self.due(now) {
            return None;
        }
        Some(self.control_with_snapshots(now, snaps))
    }

    /// One control decision at `now`, unconditionally: derive pressures
    /// from the trackers + snapshots, the predictor boost, and the
    /// routing headroom, then run [`Autopilot::control_at`].
    pub fn control_with_snapshots(
        &mut self,
        now: f64,
        snaps: &[ReplicaSnapshot],
    ) -> Vec<PrecisionDirective> {
        assert_eq!(snaps.len(), self.fsms.len(), "snapshot count mismatch");
        let pressures: Vec<f64> = (0..self.fsms.len())
            .map(|i| self.replica_pressure(now, i, &snaps[i]))
            .collect();
        let boost = self
            .predictor
            .boost(now, self.cfg.predictor_gain, self.cfg.predictor_floor_rate);
        let headroom: Vec<f64> = snaps.iter().map(slo_headroom).collect();
        self.control_at(now, &pressures, boost, &headroom)
    }

    /// Advance the surge predictor's bucket clock to `t` (idempotent;
    /// the event core's predictor component drives this on exact bucket
    /// boundaries so `rates()` stays current through arrival droughts).
    pub fn roll_predictor_to(&mut self, t: f64) {
        self.predictor.roll_to(t);
    }

    /// See [`SurgePredictor::next_boundary`].
    pub fn next_predictor_boundary(&self) -> f64 {
        self.predictor.next_boundary()
    }

    /// See [`SurgePredictor::boundary_after`].
    pub fn predictor_boundary_after(&self, t: f64) -> f64 {
        self.predictor.boundary_after(t)
    }

    /// The control law, on explicit inputs (this is the surface the
    /// property tests and the live server drive):
    ///
    /// * cluster pressure = mean replica pressure + predictor boost;
    /// * the severity integrator moves **R/2 rungs per tick** (damped;
    ///   one rung on the legacy R = 2 ladder): up above `up_pressure`,
    ///   down below `down_pressure`;
    /// * predictor-driven escalation (boost lifted the mean over the
    ///   threshold) is capped at half the severity range — the whole
    ///   fleet can be *pre-armed* to `Mixed`, but pinned FP8 requires
    ///   measured pressure;
    /// * severity rungs go to the replicas with the least SLO headroom
    ///   (highest pressure, sticky toward already-demoted replicas,
    ///   ties by the router's `slo_headroom`, then highest index), R
    ///   rungs max per measured-pressure replica, R/2 otherwise
    ///   (capped by `max_precision_rung`, scaled to the fine ladder);
    /// * each replica's FSM walks toward its assigned rung under its
    ///   dwell/cooldown discipline;
    /// * then the parallelism ladder runs, arbitrated second: for each
    ///   replica whose precision knob did *not* move this tick, TP
    ///   escalates one power-of-two rung when measured pressure persists
    ///   with the precision rung saturated at `max_precision_rung`, and
    ///   releases one rung when the replica is calm with precision fully
    ///   recovered to FP16 — both under the TP dwell/cooldown times.
    pub fn control_at(
        &mut self,
        now: f64,
        pressures: &[f64],
        boost: f64,
        headroom: &[f64],
    ) -> Vec<PrecisionDirective> {
        let n = self.fsms.len();
        assert_eq!(pressures.len(), n);
        assert_eq!(headroom.len(), n);
        self.last_control = now;
        let mean_p = pressures.iter().sum::<f64>() / n as f64;
        let cluster = mean_p + boost.max(0.0);
        let r = self.rungs;
        let half = (r / 2).max(1);
        let max_sev = r * n;

        let mut want = self.severity;
        if cluster > self.cfg.up_pressure && self.severity < max_sev {
            let measured = mean_p > self.cfg.up_pressure;
            let cap = if measured { max_sev } else { half * n };
            if self.severity < cap {
                want = (self.severity + half).min(cap);
                if !measured {
                    self.pre_escalations += 1;
                }
            }
        } else if cluster < self.cfg.down_pressure && self.severity > 0 {
            want = self.severity.saturating_sub(half);
        }
        if want != self.severity {
            self.severity = want;
            self.ladder_timeline.push((now, want));
        }

        // ladder ordering: least SLO headroom first
        let keys: Vec<f64> = (0..n)
            .map(|i| {
                pressures[i]
                    + if self.fsms[i].state != 0 {
                        self.cfg.sticky_bonus
                    } else {
                        0.0
                    }
            })
            .collect();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            keys[b]
                .partial_cmp(&keys[a])
                .unwrap()
                .then(headroom[a].partial_cmp(&headroom[b]).unwrap())
                .then(b.cmp(&a))
        });

        // distribute severity: up to R rungs per replica, most
        // pressured first — but any rung past the ladder's midpoint
        // (the FP8 half) requires *measured* pressure on that replica
        // (predictor-driven arming stops at Mixed; surplus rungs simply
        // go undistributed until pressure materializes)
        let mut rungs = vec![0usize; n];
        let mut left = self.severity;
        for &i in &order {
            if left == 0 {
                break;
            }
            let max_rung = if pressures[i] > self.cfg.up_pressure { r } else { half };
            let take = left.min(max_rung);
            rungs[i] = take;
            left -= take;
        }

        // the per-replica precision cap on the fine ladder: coarse cap
        // `max_precision_rung` scaled by R/2 fine rungs per coarse rung
        let cap_fine = self.cfg.max_precision_rung * r / 2;
        let mut out = Vec::with_capacity(n);
        let mut precision_moved = vec![false; n];
        for i in 0..n {
            let target = rungs[i].min(cap_fine);
            let before = self.fsms[i].state;
            let dir = self.fsms[i].tick(now, target, &self.cfg);
            precision_moved[i] = self.fsms[i].state != before;
            out.push(dir);
        }

        // the parallelism ladder, arbitrated second: precision is the
        // cheap knob (an iteration-level kernel switch), a TP move bills
        // a full drain + weight-move window — so TP only escalates once
        // the precision ladder has nothing left to give on that replica,
        // only releases once precision has fully recovered, and a
        // replica never moves both knobs in one control tick.
        if self.cfg.max_tp > 1 {
            for i in 0..n {
                if precision_moved[i] {
                    continue;
                }
                let state = self.fsms[i].state;
                let f = &mut self.tp_fsms[i];
                let in_state = now - f.entered_at;
                if pressures[i] > self.cfg.up_pressure
                    && state >= cap_fine
                    && f.tp < self.cfg.max_tp
                    && in_state >= self.cfg.tp_escalate_dwell_s
                    && now - f.last_release_at >= self.cfg.tp_cooldown_s
                {
                    let tp = f.tp * 2;
                    f.step_to(now, tp, false);
                } else if pressures[i] < self.cfg.down_pressure
                    && state == 0
                    && f.tp > 1
                    && in_state >= self.cfg.tp_promote_dwell_s
                {
                    let tp = f.tp / 2;
                    f.step_to(now, tp, true);
                }
            }
        }
        out
    }

    /// Bill the trailing dwell up to `end` (call once when a run ends,
    /// before reading [`Autopilot::mode_stats`]).
    pub fn finish(&mut self, end: f64) {
        let cfg = self.cfg;
        for f in &mut self.fsms {
            let state = f.state;
            f.tick(end, state, &cfg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use PrecisionDirective::{Fp16, Fp8, Mixed};

    fn ap(n: usize) -> Autopilot {
        Autopilot::new(n, AutopilotConfig::default())
    }

    #[test]
    fn tracker_windows_and_percentiles() {
        let mut t = SloTracker::default();
        for i in 0..10 {
            t.observe_tpot(i as f64, 0.010 * (i + 1) as f64);
        }
        t.evict(10.0, 100.0);
        assert_eq!(t.samples().1, 10);
        assert!((t.tpot_percentile(50.0).unwrap() - 0.055).abs() < 1e-12);
        assert!((t.tpot_percentile(100.0).unwrap() - 0.100).abs() < 1e-12);
        // window eviction: keep only the last 3 seconds of samples
        t.evict(10.0, 3.0);
        assert_eq!(t.samples().1, 3);
        assert!((t.tpot_percentile(0.0).unwrap() - 0.080).abs() < 1e-12);
        assert!(t.ttft_percentile(50.0).is_none(), "no ttft samples yet");
    }

    #[test]
    fn predictor_flags_ramps_not_steady_load() {
        let mut p = SurgePredictor::default();
        // steady 4 req/s for 30s
        for s in 0..30 {
            for k in 0..4 {
                p.observe_arrival(s as f64 + 0.2 * k as f64);
            }
        }
        let calm = p.boost(30.0, 1.0, 1.0);
        assert!(calm < 0.05, "steady load must not pre-escalate: {calm}");
        // ramp to 16 req/s
        for s in 30..36 {
            for k in 0..16 {
                p.observe_arrival(s as f64 + 0.05 * k as f64);
            }
        }
        let surging = p.boost(36.0, 1.0, 1.0);
        assert!(surging > 0.3, "4->16 req/s ramp must boost: {surging}");
        let (fast, slow) = p.rates();
        assert!(fast > slow, "fast EWMA must lead during the ramp");
        // after the surge ends the boost decays back toward zero
        for s in 36..70 {
            for k in 0..4 {
                p.observe_arrival(s as f64 + 0.2 * k as f64);
            }
        }
        assert!(p.boost(70.0, 1.0, 1.0) < 0.05, "boost must decay post-surge");
    }

    #[test]
    fn ladder_demotes_fewest_replicas_most_pressured_first() {
        let mut a = ap(3);
        let hr = [0.0; 3];
        // replica 1 is the pressured one; cluster mean just over the bar
        let pressures = [0.6, 2.0, 0.4];
        let mut dirs = a.control_at(0.0, &pressures, 0.0, &hr);
        assert_eq!(a.severity(), 1);
        assert_eq!(dirs, vec![Fp16, Mixed, Fp16], "one rung -> replica 1 arms");
        // hold the pressure: severity climbs 2 -> replica 1 walks to Fp8
        // (escalate dwell is 0.5s; ticks at 1s spacing clear it)
        dirs = a.control_at(1.0, &pressures, 0.0, &hr);
        assert_eq!(a.severity(), 2);
        assert_eq!(dirs, vec![Fp16, Fp8, Fp16], "both rungs stay on replica 1");
        // severity 3: the next-most-pressured replica (0) arms to Mixed
        dirs = a.control_at(2.0, &pressures, 0.0, &hr);
        assert_eq!(a.severity(), 3);
        assert_eq!(dirs, vec![Mixed, Fp8, Fp16]);
    }

    #[test]
    fn ladder_promotes_back_as_pressure_drains() {
        let mut a = ap(2);
        let hr = [0.0; 2];
        let mut t = 0.0;
        while a.severity() < 4 {
            a.control_at(t, &[2.0, 2.0], 0.0, &hr);
            t += 1.0;
        }
        assert_eq!(a.directives(), vec![Fp8, Fp8]);
        // drain: severity steps down one per tick, replicas walk back
        // FP8 -> Mixed -> FP16 under the promote dwell
        let mut saw_mixed = false;
        for _ in 0..40 {
            let d = a.control_at(t, &[0.1, 0.1], 0.0, &hr);
            saw_mixed |= d.contains(&Mixed);
            t += 1.0;
        }
        assert_eq!(a.severity(), 0);
        assert_eq!(a.directives(), vec![Fp16, Fp16]);
        assert!(saw_mixed, "promotion must pass through Mixed");
    }

    #[test]
    fn predictor_preescalation_is_capped_at_mixed() {
        let mut a = ap(2);
        let hr = [0.0; 2];
        // measured pressure calm, predictor screaming: severity may reach
        // n (fleet pre-armed at Mixed) but never pins FP8
        let mut t = 0.0;
        for _ in 0..20 {
            a.control_at(t, &[0.2, 0.2], 10.0, &hr);
            t += 1.0;
        }
        assert_eq!(a.severity(), 2, "pre-escalation caps at n rungs");
        assert!(a.pre_escalations >= 2);
        assert_eq!(a.directives(), vec![Mixed, Mixed]);
        // measured pressure arriving lifts the cap
        for _ in 0..20 {
            a.control_at(t, &[2.0, 2.0], 0.0, &hr);
            t += 1.0;
        }
        assert_eq!(a.directives(), vec![Fp8, Fp8]);
    }

    #[test]
    fn fsm_dwell_and_cooldown_bound_switch_times() {
        let cfg = AutopilotConfig::default();
        let mut f = ReplicaFsm::new(2);
        // rapid-fire escalate demands: first step allowed only after
        // escalate_dwell, the next only escalate_dwell later
        let mut t = 0.0;
        while f.state != 2 {
            f.tick(t, 2, &cfg);
            t += 0.01;
        }
        // then an immediate promote demand must wait out promote_dwell
        let t_fp8 = f.timeline.last().unwrap().0;
        while f.state == 2 {
            f.tick(t, 0, &cfg);
            t += 0.01;
        }
        let t_mixed = f.timeline.last().unwrap().0;
        assert!(
            t_mixed - t_fp8 >= cfg.promote_dwell_s - 1e-9,
            "promotion after {} s in FP8 (dwell {})",
            t_mixed - t_fp8,
            cfg.promote_dwell_s
        );
        // every consecutive pair of switches respects the tighter dwell
        for w in f.timeline.windows(2) {
            assert!(
                w[1].0 - w[0].0 >= cfg.escalate_dwell_s.min(cfg.promote_dwell_s) - 1e-9,
                "switch gap {} under min dwell",
                w[1].0 - w[0].0
            );
        }
        // post-promotion cooldown: re-escalation is delayed
        let t_promoted = f.timeline.last().unwrap().0;
        while f.state == 1 {
            f.tick(t, 2, &cfg);
            t += 0.01;
        }
        let t_re = f.timeline.last().unwrap().0;
        assert!(
            t_re - t_promoted >= cfg.cooldown_s - 1e-9,
            "re-escalated {} s after a promotion (cooldown {})",
            t_re - t_promoted,
            cfg.cooldown_s
        );
    }

    #[test]
    fn nan_latency_sample_no_longer_panics_the_control_loop() {
        // regression: the old percentile_of sorted with
        // partial_cmp().expect("NaN latency sample") and panicked on one
        // poisoned observation — it must now drop the sample and count it
        crate::telemetry::registry::reset_global();
        let mut t = SloTracker::default();
        t.observe_ttft(0.0, 0.050);
        t.observe_ttft(0.1, f64::NAN);
        t.observe_ttft(0.2, 0.070);
        t.observe_tpot(0.2, f64::NAN);
        let p = t.ttft_percentile(100.0).expect("real samples remain");
        assert!((p - 0.070).abs() < 1e-12, "NaN dropped, max is 0.070: {p}");
        assert!(
            t.tpot_percentile(50.0).is_none(),
            "all-NaN window reports no percentile instead of panicking"
        );
        let snap = crate::telemetry::registry::global_snapshot();
        assert_eq!(
            snap.int("autopilot.nan_dropped"),
            2,
            "each dropped NaN is counted"
        );
        crate::telemetry::registry::reset_global();
    }

    #[test]
    fn fine_ladder_matches_coarse_macro_timing_and_refines_interior() {
        let coarse_cfg = AutopilotConfig::default();
        let fine_cfg = AutopilotConfig {
            morph_rungs: 8,
            ..AutopilotConfig::default()
        };
        let mut coarse = Autopilot::new(1, coarse_cfg);
        let mut fine = Autopilot::new(1, fine_cfg);
        assert!(coarse.fine_rungs().is_none(), "legacy mode exposes no fine rungs");
        let hr = [0.0];
        let mut t = 0.0;
        // sustained measured pressure: both reach FP8 on the same ticks
        for _ in 0..40 {
            let dc = coarse.control_at(t, &[2.0], 0.0, &hr);
            let df = fine.control_at(t, &[2.0], 0.0, &hr);
            assert_eq!(dc, df, "coarse directives agree under saturation at t={t}");
            t += 0.25;
        }
        let (states, max_rung) = fine.fine_rungs().expect("morph mode");
        assert_eq!((states[0], max_rung), (8, 8));
        // drain: the fine ladder walks back through interior rungs the
        // coarse arm never visits, same endpoint-to-endpoint time
        let mut interior = false;
        for _ in 0..80 {
            coarse.control_at(t, &[0.1], 0.0, &hr);
            fine.control_at(t, &[0.1], 0.0, &hr);
            let s = fine.fine_rungs().unwrap().0[0];
            interior |= s > 0 && s < 8 && s != 4;
            t += 0.25;
        }
        assert_eq!(coarse.directives(), vec![Fp16]);
        assert_eq!(fine.fine_rungs().unwrap().0, vec![0]);
        assert!(interior, "the fine drain must visit interior rungs");
        let fp16_coarse = coarse
            .directive_timeline(0)
            .iter()
            .rev()
            .find(|&&(_, d)| d == Fp16)
            .unwrap()
            .0;
        let fp16_fine = fine
            .directive_timeline(0)
            .iter()
            .rev()
            .find(|&&(_, d)| d == Fp16)
            .unwrap()
            .0;
        // after a long FP8 stay the coarse arm's first promote move is
        // dwell-free, so the fine drain may trail by up to one coarse
        // promote dwell — never more
        assert!(
            (fp16_fine - fp16_coarse).abs() <= coarse_cfg.promote_dwell_s + 1e-9,
            "fine drain ends within one promote dwell of coarse: {fp16_fine} vs {fp16_coarse}"
        );
    }

    #[test]
    fn tp_ladder_waits_for_precision_saturation() {
        let cfg = AutopilotConfig {
            max_tp: 4,
            ..AutopilotConfig::default()
        };
        let mut a = Autopilot::new(1, cfg);
        let hr = [0.0];
        let mut t = 0.0;
        // sustained measured pressure: precision must walk its whole
        // ladder before the first TP move, and no tick moves both knobs
        for _ in 0..80 {
            a.control_at(t, &[2.0], 0.0, &hr);
            t += 0.25;
        }
        assert_eq!(a.directives(), vec![Fp8]);
        assert_eq!(a.tp_targets(), vec![4]);
        let first_tp = a.tp_timeline(0).first().unwrap().0;
        let fp8_at = a
            .directive_timeline(0)
            .iter()
            .find(|&&(_, d)| d == Fp8)
            .unwrap()
            .0;
        assert!(
            first_tp > fp8_at,
            "TP moved at {first_tp} before precision saturated at {fp8_at}"
        );
        for &(tt, _) in a.tp_timeline(0) {
            assert!(
                !a.directive_timeline(0).iter().any(|&(pt, _)| pt == tt),
                "both knobs moved in the tick at {tt}"
            );
        }
        // drain: precision must fully recover to FP16 before TP releases
        for _ in 0..200 {
            a.control_at(t, &[0.1], 0.0, &hr);
            t += 0.25;
        }
        assert_eq!(a.directives(), vec![Fp16]);
        assert_eq!(a.tp_targets(), vec![1]);
        let fp16_at = a
            .directive_timeline(0)
            .iter()
            .rev()
            .find(|&&(_, d)| d == Fp16)
            .unwrap()
            .0;
        let first_release = a
            .tp_timeline(0)
            .windows(2)
            .find(|w| w[1].1 < w[0].1)
            .unwrap()[1]
            .0;
        assert!(
            first_release > fp16_at,
            "TP released at {first_release} before precision recovered at {fp16_at}"
        );
    }

    #[test]
    fn parallelism_only_mode_pins_precision_and_climbs_tp() {
        let cfg = AutopilotConfig {
            max_tp: 4,
            max_precision_rung: 0,
            ..AutopilotConfig::default()
        };
        let mut a = Autopilot::new(2, cfg);
        let hr = [0.0; 2];
        let mut t = 0.0;
        for _ in 0..60 {
            let d = a.control_at(t, &[2.0, 0.1], 0.0, &hr);
            assert_eq!(d, vec![Fp16, Fp16], "rung 0 cap pins FP16");
            t += 0.25;
        }
        assert_eq!(a.tp_targets(), vec![4, 1], "only the pressured replica shards");
        // every TP move respects the tighter of the two TP dwells
        for w in a.tp_timeline(0).windows(2) {
            assert!(
                w[1].0 - w[0].0 >= cfg.tp_escalate_dwell_s.min(cfg.tp_promote_dwell_s) - 1e-9,
                "TP switch gap {} under dwell",
                w[1].0 - w[0].0
            );
        }
        assert_eq!(a.tp_switches(), 2, "1 -> 2 -> 4 is two moves");
    }

    #[test]
    fn default_config_disables_the_tp_ladder() {
        let mut a = ap(2);
        let hr = [0.0; 2];
        for k in 0..120 {
            a.control_at(k as f64 * 0.25, &[3.0, 3.0], 0.0, &hr);
        }
        assert_eq!(a.tp_targets(), vec![1, 1]);
        assert_eq!(a.tp_switches(), 0);
    }

    #[test]
    fn finish_bills_trailing_dwell() {
        let mut a = ap(1);
        a.control_at(0.0, &[0.0], 0.0, &[0.0]);
        a.finish(5.0);
        let st = a.mode_stats(0);
        assert!((st.dwell_s.iter().sum::<f64>() - 5.0).abs() < 1e-9);
        assert!((st.dwell_s[Fp16.rung()] - 5.0).abs() < 1e-9);
        assert_eq!(st.switches, 0);
    }
}

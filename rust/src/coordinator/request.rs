//! Request types and lifecycle.

/// Unique request id.
pub type RequestId = u64;

/// Lifecycle state of a request inside the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    /// Waiting in the admission queue.
    Queued,
    /// Admitted; prompt partially prefilled (chunked prefill in flight).
    Prefilling,
    /// Generating tokens.
    Decoding,
    /// Admitted, but its KV blocks were preempted to the host tier; the
    /// engine fetches it back (FCFS) before it decodes again.
    Offloaded,
    /// Generating tokens over **host-resident** KV blocks (attention
    /// piggybacked on the host tier instead of waiting for a resume
    /// transfer). Only entered when the policy enables piggybacking;
    /// promoted back to [`RequestState::Decoding`] when device blocks
    /// free up.
    HostDecoding,
    /// Done (completed, or evicted on error).
    Finished,
}

/// Why a request finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced max_new_tokens.
    Length,
    /// Emitted the stop byte (`;` terminates every task-grammar answer).
    Stop,
}

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    /// Prompt token ids (byte-level for the in-repo model).
    pub prompt: Vec<i32>,
    /// Generation budget.
    pub max_new_tokens: usize,
    /// Optional stop token (generation halts after emitting it).
    pub stop_token: Option<i32>,
    /// Arrival time on the engine clock, seconds.
    pub arrival: f64,

    // ---- engine-owned progress ----
    pub state: RequestState,
    /// Prompt tokens already prefilled.
    pub prefilled: usize,
    /// Generated tokens so far.
    pub generated: Vec<i32>,
    /// KV slot handle (valid once admitted).
    pub slot: Option<usize>,
    /// Clock time the first output token completed.
    pub first_token_at: Option<f64>,
    /// Clock time of the previous token (for TPOT accounting).
    pub last_token_at: Option<f64>,
    pub finish_reason: Option<FinishReason>,
    pub finished_at: Option<f64>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize, arrival: f64) -> Request {
        assert!(!prompt.is_empty(), "empty prompt");
        Request {
            id,
            prompt,
            max_new_tokens,
            stop_token: None,
            arrival,
            state: RequestState::Queued,
            prefilled: 0,
            generated: Vec::new(),
            slot: None,
            first_token_at: None,
            last_token_at: None,
            finish_reason: None,
            finished_at: None,
        }
    }

    pub fn with_stop(mut self, tok: i32) -> Request {
        self.stop_token = Some(tok);
        self
    }

    /// Current sequence length in the KV cache (prefilled + generated).
    pub fn context_len(&self) -> usize {
        self.prefilled + self.generated.len()
    }

    /// Prompt tokens still to prefill.
    pub fn remaining_prompt(&self) -> usize {
        self.prompt.len() - self.prefilled
    }

    pub fn is_finished(&self) -> bool {
        self.state == RequestState::Finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_counters() {
        let mut r = Request::new(1, vec![1, 2, 3], 8, 0.0);
        assert_eq!(r.remaining_prompt(), 3);
        assert_eq!(r.context_len(), 0);
        r.prefilled = 3;
        r.generated.push(7);
        assert_eq!(r.remaining_prompt(), 0);
        assert_eq!(r.context_len(), 4);
        assert!(!r.is_finished());
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn rejects_empty_prompt() {
        Request::new(1, vec![], 8, 0.0);
    }
}

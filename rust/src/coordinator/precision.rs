//! The dual-precision controller — the paper's §3.2 proposal, made
//! concrete: per scheduling iteration, pick FP16 (quality) or FP8
//! (throughput) from load and SLO-pressure signals, with hysteresis so the
//! engine does not flap between modes.
//!
//! Signals:
//! * EWMA of recent TPOT vs the SLO target (33.3 ms in the paper),
//! * queue depth (requests waiting for admission),
//! * KV block utilization (memory pressure limits batch growth).

/// SLO targets (industry-standard values from the paper's §1).
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// Time-per-output-token target, seconds (paper: 33.3 ms).
    pub tpot_target: f64,
    /// Time-to-first-token target, seconds (paper: 200 ms).
    pub ttft_target: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            tpot_target: 0.0333,
            ttft_target: 0.200,
        }
    }
}

/// Which precision the engine should run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Fp16,
    Fp8,
}

/// A cluster-level instruction to one replica's controller — the three
/// rungs of the autopilot's per-replica ladder. `Mixed` hands the
/// iteration-level decision back to the local policy; the pinned rungs
/// override it in either direction (an FP16 *quality lock* during calm
/// periods is as much a directive as an FP8 demotion during a surge).
///
/// This subsumes the PR-1 `set_forced(Option<Precision>)` API:
/// `Some(p)` maps to the pinned rung for `p`, `None` to `Mixed`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PrecisionDirective {
    /// Pin FP16 (quality lock).
    Fp16,
    /// Local policy decides per iteration (the default).
    Mixed,
    /// Pin FP8 (throughput lock).
    Fp8,
}

impl PrecisionDirective {
    /// Ladder rung index: 0 = Fp16, 1 = Mixed, 2 = Fp8. The autopilot's
    /// escalation ladder and the dwell accounting both index by this.
    pub fn rung(self) -> usize {
        match self {
            PrecisionDirective::Fp16 => 0,
            PrecisionDirective::Mixed => 1,
            PrecisionDirective::Fp8 => 2,
        }
    }

    /// The directive one rung toward `target` (used by the per-replica
    /// state machine: FP16 → Mixed → FP8 and back, never skipping Mixed).
    pub fn step_toward(self, target: PrecisionDirective) -> PrecisionDirective {
        use PrecisionDirective::*;
        match self.rung().cmp(&target.rung()) {
            std::cmp::Ordering::Equal => self,
            std::cmp::Ordering::Less => match self {
                Fp16 => Mixed,
                _ => Fp8,
            },
            std::cmp::Ordering::Greater => match self {
                Fp8 => Mixed,
                _ => Fp16,
            },
        }
    }
}

/// A per-layer precision schedule: the generalization of the three-rung
/// whole-replica directive to per-layer morphing (MorphServe, arxiv
/// 2506.02006). Layers are ranked once at startup by quantization
/// sensitivity (least sensitive first — see
/// `eval::quanterr::gemm_output_error`); demotion always takes a prefix
/// of that order, so "k layers demoted" is a single integer walked up
/// and down by the autopilot's fine ladder. The endpoints (`k == 0`,
/// `k == n`) are exactly the old `Fp16` / `Fp8` directives — every
/// legacy caller, golden trace, and bit-identity test stays valid.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSchedule {
    /// Layer indices, least sensitive first — the demotion order.
    order: Vec<usize>,
    /// Inverse permutation: `rank[layer]` = position of `layer` in
    /// `order` (demoted iff `rank[layer] < demoted`).
    rank: Vec<usize>,
    /// Number of layers currently demoted (always a prefix of `order`).
    demoted: usize,
    /// `err_prefix[k]` = quality-proxy error of demoting the first `k`
    /// layers in `order`, normalized so `err_prefix[n] == 1.0` (the
    /// all-FP8 error). Monotone non-decreasing by construction.
    err_prefix: Vec<f64>,
}

impl LayerSchedule {
    /// Build from a per-layer sensitivity ranking (higher = more
    /// quality-sensitive, demoted later). Sensitivities must be finite
    /// and non-negative; ties break toward the lower layer index so the
    /// order is deterministic.
    pub fn from_sensitivity(sensitivity: &[f64]) -> LayerSchedule {
        assert!(!sensitivity.is_empty(), "schedule needs at least one layer");
        for (i, s) in sensitivity.iter().enumerate() {
            assert!(
                s.is_finite() && *s >= 0.0,
                "layer {i} sensitivity {s} must be finite and non-negative"
            );
        }
        let n = sensitivity.len();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| sensitivity[a].total_cmp(&sensitivity[b]).then(a.cmp(&b)));
        let total: f64 = sensitivity.iter().sum();
        let mut err_prefix = vec![0.0; n + 1];
        let mut acc = 0.0;
        for (k, &l) in order.iter().enumerate() {
            acc += sensitivity[l];
            err_prefix[k + 1] = if total > 0.0 {
                acc / total
            } else {
                // degenerate all-zero ranking: uniform per-layer error
                (k + 1) as f64 / n as f64
            };
        }
        Self::assemble(order, err_prefix)
    }

    /// Build from an explicit demotion order (a permutation of
    /// `0..order.len()`), with a uniform per-layer quality proxy.
    pub fn from_order(order: Vec<usize>) -> LayerSchedule {
        let n = order.len();
        assert!(n > 0, "schedule needs at least one layer");
        let err_prefix = (0..=n).map(|k| k as f64 / n as f64).collect();
        Self::assemble(order, err_prefix)
    }

    /// The trivial schedule: layers demote in index order.
    pub fn identity(n_layers: usize) -> LayerSchedule {
        Self::from_order((0..n_layers).collect())
    }

    fn assemble(order: Vec<usize>, err_prefix: Vec<f64>) -> LayerSchedule {
        let n = order.len();
        let mut rank = vec![usize::MAX; n];
        for (pos, &l) in order.iter().enumerate() {
            assert!(l < n, "layer index {l} out of range for {n} layers");
            assert!(rank[l] == usize::MAX, "layer {l} repeated in the order");
            rank[l] = pos;
        }
        LayerSchedule {
            order,
            rank,
            demoted: 0,
            err_prefix,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.order.len()
    }

    /// Number of layers currently demoted to FP8.
    pub fn demoted_layers(&self) -> usize {
        self.demoted
    }

    /// Demotion order, least sensitive first.
    pub fn order(&self) -> &[usize] {
        &self.order
    }

    /// Demote exactly the `k` least-sensitive layers (clamped to `n`).
    pub fn set_demoted(&mut self, k: usize) {
        self.demoted = k.min(self.n_layers());
    }

    /// Is `layer` currently served at FP8?
    pub fn is_demoted(&self, layer: usize) -> bool {
        self.rank[layer] < self.demoted
    }

    /// Per-layer demotion flags, indexed by layer.
    pub fn cold_mask(&self) -> Vec<bool> {
        (0..self.n_layers()).map(|l| self.is_demoted(l)).collect()
    }

    /// Fraction of layers demoted — exactly `0.0` / `1.0` at the
    /// endpoints so the elastic KV watermark reproduces the legacy
    /// binary pressure flag bit for bit there.
    pub fn demoted_fraction(&self) -> f64 {
        let n = self.n_layers();
        if self.demoted == 0 {
            0.0
        } else if self.demoted >= n {
            1.0
        } else {
            self.demoted as f64 / n as f64
        }
    }

    /// How many layers a fine ladder rung demotes: `rung == 0` → none,
    /// `rung == max_rung` → all, interior rungs round up so every
    /// non-zero rung demotes at least one layer.
    pub fn demoted_for_rung(rung: usize, max_rung: usize, n_layers: usize) -> usize {
        assert!(max_rung >= 1 && rung <= max_rung, "rung {rung} > max {max_rung}");
        (rung * n_layers).div_ceil(max_rung).min(n_layers)
    }

    /// Quality-proxy error of demoting the `k` least-sensitive layers,
    /// in `[0, 1]` (1 = the all-FP8 error). The morph bench integrates
    /// this per iteration to score the quality axis of the frontier.
    pub fn demotion_error(&self, k: usize) -> f64 {
        self.err_prefix[k.min(self.n_layers())]
    }
}

/// Operating policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecisionPolicy {
    /// Always FP16 (the quality baseline).
    Fp16Only,
    /// Always FP8 (the throughput baseline).
    Fp8Only,
    /// NestedFP dual-precision: switch per iteration.
    Dual,
}

/// Controller state.
#[derive(Clone, Debug)]
pub struct PrecisionController {
    pub policy: PrecisionPolicy,
    pub slo: SloConfig,
    current: Precision,
    /// Externally imposed rung (cluster autopilot / staged escalation):
    /// pinned rungs override the local policy until set back to `Mixed`.
    directive: PrecisionDirective,
    /// EWMA of observed TPOT, seconds.
    ewma_tpot: f64,
    /// Most recent worst-gap observation (fast burst signal).
    last_tpot: f64,
    ewma_alpha: f64,
    /// Iterations remaining before another switch is allowed.
    dwell: usize,
    min_dwell_iters: usize,
    /// Switch count (reported in experiments).
    pub switches: usize,
    /// Iterations spent in each precision.
    pub iters_fp16: usize,
    pub iters_fp8: usize,
    /// Optional per-layer schedule (per-layer morphing). `None` keeps
    /// every legacy path bit-identical.
    schedule: Option<LayerSchedule>,
    /// Interior fine-ladder pin: `Some(k)` serves exactly `k` demoted
    /// layers regardless of the local policy (the autopilot's interior
    /// rungs). Cleared by any whole-replica directive.
    partial: Option<usize>,
    /// Quality-proxy accounting under a schedule: per-iteration
    /// [`LayerSchedule::demotion_error`] integrated over the run.
    pub sched_err_iters: f64,
    /// Iterations accounted in `sched_err_iters`.
    pub sched_iters: usize,
}

/// Escalate to FP8 when the TPOT EWMA exceeds this fraction of the SLO.
const HIGH_WATER: f64 = 0.85;
/// Return to FP16 when it falls below this fraction.
const LOW_WATER: f64 = 0.60;
/// Queue depth that forces FP8 regardless of latency (burst absorber —
/// queued requests mean imminent prefill iterations that will stretch
/// running sequences' inter-token gaps).
const QUEUE_PANIC: usize = 3;
/// A single observed gap beyond this fraction of the SLO escalates
/// immediately (the EWMA alone reacts too slowly for second-level bursts).
const SPIKE_WATER: f64 = 0.80;

impl PrecisionController {
    pub fn new(policy: PrecisionPolicy, slo: SloConfig) -> PrecisionController {
        PrecisionController {
            policy,
            slo,
            current: match policy {
                PrecisionPolicy::Fp8Only => Precision::Fp8,
                _ => Precision::Fp16,
            },
            directive: PrecisionDirective::Mixed,
            ewma_tpot: 0.0,
            last_tpot: 0.0,
            ewma_alpha: 0.25,
            dwell: 0,
            min_dwell_iters: 8,
            switches: 0,
            iters_fp16: 0,
            iters_fp8: 0,
            schedule: None,
            partial: None,
            sched_err_iters: 0.0,
            sched_iters: 0,
        }
    }

    /// Record an iteration's observed decode latency (== TPOT for the
    /// sequences in the batch).
    pub fn observe_tpot(&mut self, tpot_s: f64) {
        self.last_tpot = tpot_s;
        if self.ewma_tpot == 0.0 {
            self.ewma_tpot = tpot_s;
        } else {
            self.ewma_tpot =
                self.ewma_alpha * tpot_s + (1.0 - self.ewma_alpha) * self.ewma_tpot;
        }
    }

    pub fn ewma_tpot(&self) -> f64 {
        self.ewma_tpot
    }

    /// Apply a cluster-level directive. Pinned rungs (`Fp16` / `Fp8`)
    /// override the local policy until the directive returns to `Mixed`;
    /// the autopilot's per-replica state machine is the only caller that
    /// should drive this per control tick (it owns the dwell/cooldown
    /// discipline — the controller just obeys).
    pub fn apply_directive(&mut self, d: PrecisionDirective) {
        self.partial = None;
        self.directive = d;
    }

    /// The current cluster-level directive.
    pub fn directive(&self) -> PrecisionDirective {
        self.directive
    }

    /// Install (or clear) a per-layer schedule. The schedule's demotion
    /// count is synced to the controller's current precision so the
    /// hand-off is seamless at either endpoint.
    pub fn set_schedule(&mut self, s: Option<LayerSchedule>) {
        self.partial = None;
        self.schedule = s;
        self.sync_schedule(self.current);
    }

    /// The installed per-layer schedule, if any.
    pub fn schedule(&self) -> Option<&LayerSchedule> {
        self.schedule.as_ref()
    }

    /// Fraction of layers currently demoted under the schedule (`None`
    /// without one) — the elastic KV watermark's input.
    pub fn demoted_fraction(&self) -> Option<f64> {
        self.schedule.as_ref().map(|s| s.demoted_fraction())
    }

    /// Pin the schedule's endpoints to a whole-replica precision.
    fn sync_schedule(&mut self, p: Precision) {
        if let Some(s) = &mut self.schedule {
            let n = s.n_layers();
            s.set_demoted(match p {
                Precision::Fp16 => 0,
                Precision::Fp8 => n,
            });
        }
    }

    /// Apply one rung of the autopilot's fine ladder (`0..=max_rung`).
    /// The endpoints are exactly [`PrecisionController::apply_directive`]
    /// with `Fp16` / `Fp8` — bit-identical to the legacy coarse ladder;
    /// interior rungs pin a partial schedule (`k` least-sensitive layers
    /// demoted). Without an installed schedule an interior rung degrades
    /// to the legacy `Mixed` directive (local policy autonomy).
    pub fn apply_layer_rung(&mut self, rung: usize, max_rung: usize) {
        assert!(max_rung >= 1 && rung <= max_rung, "rung {rung} > max {max_rung}");
        if rung == 0 {
            self.apply_directive(PrecisionDirective::Fp16);
        } else if rung == max_rung {
            self.apply_directive(PrecisionDirective::Fp8);
        } else if let Some(s) = &mut self.schedule {
            let k = LayerSchedule::demoted_for_rung(rung, max_rung, s.n_layers());
            s.set_demoted(k);
            self.directive = PrecisionDirective::Mixed;
            self.partial = Some(k);
        } else {
            self.apply_directive(PrecisionDirective::Mixed);
        }
    }

    /// Impose (or clear) an external precision override — the PR-1 API,
    /// now a thin shim over [`PrecisionController::apply_directive`]. A
    /// cluster router uses this to demote one replica to FP8 during a
    /// surge while other replicas keep serving FP16. While pinned,
    /// [`PrecisionController::decide`] ignores the local policy; clearing
    /// returns control to it (after the usual dwell, to avoid flapping).
    pub fn set_forced(&mut self, p: Option<Precision>) {
        self.apply_directive(match p {
            Some(Precision::Fp16) => PrecisionDirective::Fp16,
            Some(Precision::Fp8) => PrecisionDirective::Fp8,
            None => PrecisionDirective::Mixed,
        });
    }

    /// The current external override, if any (`Mixed` reads as `None`).
    pub fn forced(&self) -> Option<Precision> {
        match self.directive {
            PrecisionDirective::Fp16 => Some(Precision::Fp16),
            PrecisionDirective::Fp8 => Some(Precision::Fp8),
            PrecisionDirective::Mixed => None,
        }
    }

    /// Decide the precision for the next iteration.
    pub fn decide(&mut self, queue_depth: usize, kv_utilization: f64) -> Precision {
        if let Some(k) = self.partial {
            // interior fine-ladder pin: the backend serves k demoted
            // layers; the majority precision books the legacy iteration
            // counters so fp16_fraction stays meaningful
            let (n, err) = {
                let s = self
                    .schedule
                    .as_ref()
                    .expect("a partial pin implies an installed schedule");
                (s.n_layers(), s.demotion_error(k))
            };
            let p = if 2 * k >= n { Precision::Fp8 } else { Precision::Fp16 };
            if p != self.current {
                self.switches += 1;
                self.dwell = self.min_dwell_iters;
                self.current = p;
            }
            match p {
                Precision::Fp16 => self.iters_fp16 += 1,
                Precision::Fp8 => self.iters_fp8 += 1,
            }
            self.sched_err_iters += err;
            self.sched_iters += 1;
            return p;
        }
        if let Some(f) = self.forced() {
            if f != self.current {
                self.switches += 1;
                self.dwell = self.min_dwell_iters;
                self.current = f;
            }
            match f {
                Precision::Fp16 => self.iters_fp16 += 1,
                Precision::Fp8 => self.iters_fp8 += 1,
            }
            self.sync_schedule(f);
            self.account_schedule();
            return f;
        }
        let decided = match self.policy {
            PrecisionPolicy::Fp16Only => Precision::Fp16,
            PrecisionPolicy::Fp8Only => Precision::Fp8,
            PrecisionPolicy::Dual => {
                if self.dwell > 0 {
                    self.dwell -= 1;
                    self.current
                } else {
                    let pressure = self.ewma_tpot / self.slo.tpot_target;
                    let spike = self.last_tpot / self.slo.tpot_target;
                    let want = if queue_depth >= QUEUE_PANIC || kv_utilization > 0.90 {
                        Precision::Fp8
                    } else if pressure > HIGH_WATER || spike > SPIKE_WATER {
                        Precision::Fp8
                    } else if pressure < LOW_WATER
                        && spike < LOW_WATER
                        && queue_depth < QUEUE_PANIC
                    {
                        Precision::Fp16
                    } else {
                        self.current // hysteresis band: hold
                    };
                    if want != self.current {
                        self.switches += 1;
                        self.dwell = self.min_dwell_iters;
                        self.current = want;
                    }
                    self.current
                }
            }
        };
        match decided {
            Precision::Fp16 => self.iters_fp16 += 1,
            Precision::Fp8 => self.iters_fp8 += 1,
        }
        self.sync_schedule(decided);
        self.account_schedule();
        decided
    }

    /// Book one iteration of the schedule's quality proxy (no-op
    /// without a schedule — the legacy paths never touch these fields).
    fn account_schedule(&mut self) {
        let err = self
            .schedule
            .as_ref()
            .map(|s| s.demotion_error(s.demoted_layers()));
        if let Some(err) = err {
            self.sched_err_iters += err;
            self.sched_iters += 1;
        }
    }

    /// Fraction of iterations served at FP16 (the paper reports dual-mode
    /// preserving FP16 for >68% of the time on the Azure trace slice).
    pub fn fp16_fraction(&self) -> f64 {
        let total = self.iters_fp16 + self.iters_fp8;
        if total == 0 {
            1.0
        } else {
            self.iters_fp16 as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> PrecisionController {
        PrecisionController::new(PrecisionPolicy::Dual, SloConfig::default())
    }

    #[test]
    fn fixed_policies_never_switch() {
        let mut c16 = PrecisionController::new(PrecisionPolicy::Fp16Only, SloConfig::default());
        let mut c8 = PrecisionController::new(PrecisionPolicy::Fp8Only, SloConfig::default());
        for _ in 0..100 {
            c16.observe_tpot(1.0); // terrible latency
            assert_eq!(c16.decide(100, 1.0), Precision::Fp16);
            c8.observe_tpot(0.0001);
            assert_eq!(c8.decide(0, 0.0), Precision::Fp8);
        }
        assert_eq!(c16.switches, 0);
        assert_eq!(c8.switches, 0);
    }

    #[test]
    fn escalates_under_latency_pressure() {
        let mut c = ctl();
        for _ in 0..10 {
            c.observe_tpot(0.040); // above 33.3ms SLO
        }
        assert_eq!(c.decide(0, 0.2), Precision::Fp8);
        assert_eq!(c.switches, 1);
    }

    #[test]
    fn recovers_when_load_drops() {
        let mut c = ctl();
        for _ in 0..10 {
            c.observe_tpot(0.040);
        }
        assert_eq!(c.decide(0, 0.2), Precision::Fp8);
        // latency falls well under the low-water mark
        for _ in 0..40 {
            c.observe_tpot(0.010);
        }
        // burn through the dwell period
        let mut last = Precision::Fp8;
        for _ in 0..10 {
            last = c.decide(0, 0.2);
        }
        assert_eq!(last, Precision::Fp16);
        assert_eq!(c.switches, 2);
    }

    #[test]
    fn hysteresis_prevents_flapping() {
        let mut c = ctl();
        // oscillate right around the high-water mark
        let mut switches_seen = Vec::new();
        for i in 0..200 {
            let t = if i % 2 == 0 { 0.0285 } else { 0.0282 }; // ~0.85*SLO
            c.observe_tpot(t);
            c.decide(0, 0.2);
            switches_seen.push(c.switches);
        }
        assert!(
            c.switches <= 4,
            "controller flapped {} times around the threshold",
            c.switches
        );
    }

    #[test]
    fn queue_panic_forces_fp8() {
        let mut c = ctl();
        c.observe_tpot(0.001); // latency is fine
        assert_eq!(c.decide(QUEUE_PANIC, 0.1), Precision::Fp8);
    }

    #[test]
    fn kv_pressure_forces_fp8() {
        let mut c = ctl();
        c.observe_tpot(0.001);
        assert_eq!(c.decide(0, 0.95), Precision::Fp8);
    }

    #[test]
    fn forced_demotion_overrides_policy() {
        // an FP16-only replica demoted by the cluster router serves FP8
        let mut c = PrecisionController::new(PrecisionPolicy::Fp16Only, SloConfig::default());
        assert_eq!(c.decide(0, 0.0), Precision::Fp16);
        c.set_forced(Some(Precision::Fp8));
        for _ in 0..5 {
            assert_eq!(c.decide(0, 0.0), Precision::Fp8);
        }
        assert_eq!(c.switches, 1, "one demotion, no flapping while forced");
        c.set_forced(None);
        assert_eq!(c.decide(0, 0.0), Precision::Fp16);
        assert!(c.iters_fp8 == 5 && c.iters_fp16 >= 2);
    }

    #[test]
    fn forced_release_respects_dwell_under_dual() {
        let mut c = ctl();
        c.observe_tpot(0.001); // no local pressure at all
        c.set_forced(Some(Precision::Fp8));
        assert_eq!(c.decide(0, 0.0), Precision::Fp8);
        c.set_forced(None);
        // dwell keeps the forced mode briefly, then the (calm) signals
        // bring the replica back to FP16 — no instant flap
        let mut saw_fp16 = false;
        for _ in 0..16 {
            c.observe_tpot(0.001);
            if c.decide(0, 0.0) == Precision::Fp16 {
                saw_fp16 = true;
            }
        }
        assert!(saw_fp16, "never recovered to fp16 after release");
        assert!(c.switches <= 2);
    }

    #[test]
    fn directive_rungs_and_stepping() {
        use PrecisionDirective::*;
        assert_eq!(Fp16.rung(), 0);
        assert_eq!(Mixed.rung(), 1);
        assert_eq!(Fp8.rung(), 2);
        // one rung at a time, never skipping Mixed
        assert_eq!(Fp16.step_toward(Fp8), Mixed);
        assert_eq!(Mixed.step_toward(Fp8), Fp8);
        assert_eq!(Fp8.step_toward(Fp16), Mixed);
        assert_eq!(Mixed.step_toward(Fp16), Fp16);
        assert_eq!(Fp8.step_toward(Fp8), Fp8);
        assert_eq!(Mixed.step_toward(Mixed), Mixed);
    }

    #[test]
    fn directive_fp16_quality_locks_a_pressured_dual_controller() {
        // under load a Dual controller wants FP8; a pinned Fp16 directive
        // (the autopilot's quality lock) must win
        let mut c = ctl();
        c.apply_directive(PrecisionDirective::Fp16);
        for _ in 0..20 {
            c.observe_tpot(0.200); // 6x the SLO
            assert_eq!(c.decide(10, 0.99), Precision::Fp16);
        }
        assert_eq!(c.forced(), Some(Precision::Fp16));
        // releasing to Mixed hands control back: pressure drives FP8
        c.apply_directive(PrecisionDirective::Mixed);
        assert_eq!(c.forced(), None);
        let mut last = Precision::Fp16;
        for _ in 0..12 {
            c.observe_tpot(0.200);
            last = c.decide(10, 0.99);
        }
        assert_eq!(last, Precision::Fp8);
    }

    #[test]
    fn set_forced_is_a_directive_shim() {
        let mut c = ctl();
        c.set_forced(Some(Precision::Fp8));
        assert_eq!(c.directive(), PrecisionDirective::Fp8);
        c.set_forced(Some(Precision::Fp16));
        assert_eq!(c.directive(), PrecisionDirective::Fp16);
        c.set_forced(None);
        assert_eq!(c.directive(), PrecisionDirective::Mixed);
    }

    #[test]
    fn fp16_fraction_accounting() {
        let mut c = ctl();
        for _ in 0..10 {
            c.observe_tpot(0.001);
            c.decide(0, 0.0);
        }
        assert_eq!(c.fp16_fraction(), 1.0);
    }

    #[test]
    fn schedule_demotes_least_sensitive_first() {
        let sens = [0.5, 0.1, 0.9, 0.3];
        let mut s = LayerSchedule::from_sensitivity(&sens);
        assert_eq!(s.order(), &[1, 3, 0, 2], "ascending sensitivity");
        assert_eq!(s.demoted_layers(), 0);
        assert_eq!(s.demoted_fraction(), 0.0);
        s.set_demoted(2);
        assert!(s.is_demoted(1) && s.is_demoted(3));
        assert!(!s.is_demoted(0) && !s.is_demoted(2));
        assert_eq!(s.cold_mask(), vec![false, true, false, true]);
        s.set_demoted(99);
        assert_eq!(s.demoted_layers(), 4, "clamped to n");
        assert_eq!(s.demoted_fraction(), 1.0);
        // the error prefix is monotone and normalized
        let mut prev = -1.0;
        for k in 0..=4 {
            let e = s.demotion_error(k);
            assert!(e >= prev, "err must be monotone in k");
            prev = e;
        }
        assert_eq!(s.demotion_error(0), 0.0);
        assert!((s.demotion_error(4) - 1.0).abs() < 1e-12);
        // sensitivity ties break toward the lower layer index
        let tied = LayerSchedule::from_sensitivity(&[0.2, 0.2, 0.1]);
        assert_eq!(tied.order(), &[2, 0, 1]);
    }

    #[test]
    fn rung_to_layer_mapping_covers_endpoints() {
        for (r, n) in [(8usize, 32usize), (8, 5), (2, 32), (16, 3)] {
            assert_eq!(LayerSchedule::demoted_for_rung(0, r, n), 0);
            assert_eq!(LayerSchedule::demoted_for_rung(r, r, n), n);
            let mut prev = 0;
            for rung in 0..=r {
                let k = LayerSchedule::demoted_for_rung(rung, r, n);
                assert!(k >= prev, "monotone in the rung");
                assert!(rung == 0 || k >= 1, "non-zero rung demotes >= 1 layer");
                prev = k;
            }
        }
    }

    #[test]
    fn schedule_endpoints_behave_like_the_old_directives() {
        // a controller with a schedule at rung 0 / max must decide
        // exactly like one driven by the legacy Fp16/Fp8 directives
        let mut with = ctl();
        with.set_schedule(Some(LayerSchedule::identity(32)));
        let mut without = ctl();
        for (rung, d) in [(0usize, PrecisionDirective::Fp16), (8, PrecisionDirective::Fp8)] {
            with.apply_layer_rung(rung, 8);
            without.apply_directive(d);
            for _ in 0..5 {
                with.observe_tpot(0.02);
                without.observe_tpot(0.02);
                assert_eq!(with.decide(1, 0.5), without.decide(1, 0.5), "rung {rung}");
            }
        }
        assert_eq!(with.switches, without.switches);
        assert_eq!(with.iters_fp16, without.iters_fp16);
        assert_eq!(with.iters_fp8, without.iters_fp8);
        assert_eq!(with.demoted_fraction(), Some(1.0));
    }

    #[test]
    fn interior_rung_pins_a_partial_schedule() {
        let mut c = ctl();
        c.set_schedule(Some(LayerSchedule::identity(32)));
        c.apply_layer_rung(3, 8);
        let p = c.decide(0, 0.0);
        assert_eq!(p, Precision::Fp16, "12/32 demoted: FP16 majority");
        let s = c.schedule().unwrap();
        assert_eq!(s.demoted_layers(), 12, "3/8 of 32 layers");
        assert_eq!(c.demoted_fraction(), Some(12.0 / 32.0));
        assert!(c.sched_iters == 1 && c.sched_err_iters > 0.0);
        // walking back to the FP16 endpoint clears the pin
        c.apply_layer_rung(0, 8);
        assert_eq!(c.decide(0, 0.0), Precision::Fp16);
        assert_eq!(c.demoted_fraction(), Some(0.0));
        // without a schedule an interior rung degrades to Mixed
        let mut bare = ctl();
        bare.apply_layer_rung(4, 8);
        assert_eq!(bare.directive(), PrecisionDirective::Mixed);
        assert_eq!(bare.forced(), None);
    }
}

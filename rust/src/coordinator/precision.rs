//! The dual-precision controller — the paper's §3.2 proposal, made
//! concrete: per scheduling iteration, pick FP16 (quality) or FP8
//! (throughput) from load and SLO-pressure signals, with hysteresis so the
//! engine does not flap between modes.
//!
//! Signals:
//! * EWMA of recent TPOT vs the SLO target (33.3 ms in the paper),
//! * queue depth (requests waiting for admission),
//! * KV block utilization (memory pressure limits batch growth).

/// SLO targets (industry-standard values from the paper's §1).
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// Time-per-output-token target, seconds (paper: 33.3 ms).
    pub tpot_target: f64,
    /// Time-to-first-token target, seconds (paper: 200 ms).
    pub ttft_target: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            tpot_target: 0.0333,
            ttft_target: 0.200,
        }
    }
}

/// Which precision the engine should run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Fp16,
    Fp8,
}

/// A cluster-level instruction to one replica's controller — the three
/// rungs of the autopilot's per-replica ladder. `Mixed` hands the
/// iteration-level decision back to the local policy; the pinned rungs
/// override it in either direction (an FP16 *quality lock* during calm
/// periods is as much a directive as an FP8 demotion during a surge).
///
/// This subsumes the PR-1 `set_forced(Option<Precision>)` API:
/// `Some(p)` maps to the pinned rung for `p`, `None` to `Mixed`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PrecisionDirective {
    /// Pin FP16 (quality lock).
    Fp16,
    /// Local policy decides per iteration (the default).
    Mixed,
    /// Pin FP8 (throughput lock).
    Fp8,
}

impl PrecisionDirective {
    /// Ladder rung index: 0 = Fp16, 1 = Mixed, 2 = Fp8. The autopilot's
    /// escalation ladder and the dwell accounting both index by this.
    pub fn rung(self) -> usize {
        match self {
            PrecisionDirective::Fp16 => 0,
            PrecisionDirective::Mixed => 1,
            PrecisionDirective::Fp8 => 2,
        }
    }

    /// The directive one rung toward `target` (used by the per-replica
    /// state machine: FP16 → Mixed → FP8 and back, never skipping Mixed).
    pub fn step_toward(self, target: PrecisionDirective) -> PrecisionDirective {
        use PrecisionDirective::*;
        match self.rung().cmp(&target.rung()) {
            std::cmp::Ordering::Equal => self,
            std::cmp::Ordering::Less => match self {
                Fp16 => Mixed,
                _ => Fp8,
            },
            std::cmp::Ordering::Greater => match self {
                Fp8 => Mixed,
                _ => Fp16,
            },
        }
    }
}

/// Operating policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecisionPolicy {
    /// Always FP16 (the quality baseline).
    Fp16Only,
    /// Always FP8 (the throughput baseline).
    Fp8Only,
    /// NestedFP dual-precision: switch per iteration.
    Dual,
}

/// Controller state.
#[derive(Clone, Debug)]
pub struct PrecisionController {
    pub policy: PrecisionPolicy,
    pub slo: SloConfig,
    current: Precision,
    /// Externally imposed rung (cluster autopilot / staged escalation):
    /// pinned rungs override the local policy until set back to `Mixed`.
    directive: PrecisionDirective,
    /// EWMA of observed TPOT, seconds.
    ewma_tpot: f64,
    /// Most recent worst-gap observation (fast burst signal).
    last_tpot: f64,
    ewma_alpha: f64,
    /// Iterations remaining before another switch is allowed.
    dwell: usize,
    min_dwell_iters: usize,
    /// Switch count (reported in experiments).
    pub switches: usize,
    /// Iterations spent in each precision.
    pub iters_fp16: usize,
    pub iters_fp8: usize,
}

/// Escalate to FP8 when the TPOT EWMA exceeds this fraction of the SLO.
const HIGH_WATER: f64 = 0.85;
/// Return to FP16 when it falls below this fraction.
const LOW_WATER: f64 = 0.60;
/// Queue depth that forces FP8 regardless of latency (burst absorber —
/// queued requests mean imminent prefill iterations that will stretch
/// running sequences' inter-token gaps).
const QUEUE_PANIC: usize = 3;
/// A single observed gap beyond this fraction of the SLO escalates
/// immediately (the EWMA alone reacts too slowly for second-level bursts).
const SPIKE_WATER: f64 = 0.80;

impl PrecisionController {
    pub fn new(policy: PrecisionPolicy, slo: SloConfig) -> PrecisionController {
        PrecisionController {
            policy,
            slo,
            current: match policy {
                PrecisionPolicy::Fp8Only => Precision::Fp8,
                _ => Precision::Fp16,
            },
            directive: PrecisionDirective::Mixed,
            ewma_tpot: 0.0,
            last_tpot: 0.0,
            ewma_alpha: 0.25,
            dwell: 0,
            min_dwell_iters: 8,
            switches: 0,
            iters_fp16: 0,
            iters_fp8: 0,
        }
    }

    /// Record an iteration's observed decode latency (== TPOT for the
    /// sequences in the batch).
    pub fn observe_tpot(&mut self, tpot_s: f64) {
        self.last_tpot = tpot_s;
        if self.ewma_tpot == 0.0 {
            self.ewma_tpot = tpot_s;
        } else {
            self.ewma_tpot =
                self.ewma_alpha * tpot_s + (1.0 - self.ewma_alpha) * self.ewma_tpot;
        }
    }

    pub fn ewma_tpot(&self) -> f64 {
        self.ewma_tpot
    }

    /// Apply a cluster-level directive. Pinned rungs (`Fp16` / `Fp8`)
    /// override the local policy until the directive returns to `Mixed`;
    /// the autopilot's per-replica state machine is the only caller that
    /// should drive this per control tick (it owns the dwell/cooldown
    /// discipline — the controller just obeys).
    pub fn apply_directive(&mut self, d: PrecisionDirective) {
        self.directive = d;
    }

    /// The current cluster-level directive.
    pub fn directive(&self) -> PrecisionDirective {
        self.directive
    }

    /// Impose (or clear) an external precision override — the PR-1 API,
    /// now a thin shim over [`PrecisionController::apply_directive`]. A
    /// cluster router uses this to demote one replica to FP8 during a
    /// surge while other replicas keep serving FP16. While pinned,
    /// [`PrecisionController::decide`] ignores the local policy; clearing
    /// returns control to it (after the usual dwell, to avoid flapping).
    pub fn set_forced(&mut self, p: Option<Precision>) {
        self.apply_directive(match p {
            Some(Precision::Fp16) => PrecisionDirective::Fp16,
            Some(Precision::Fp8) => PrecisionDirective::Fp8,
            None => PrecisionDirective::Mixed,
        });
    }

    /// The current external override, if any (`Mixed` reads as `None`).
    pub fn forced(&self) -> Option<Precision> {
        match self.directive {
            PrecisionDirective::Fp16 => Some(Precision::Fp16),
            PrecisionDirective::Fp8 => Some(Precision::Fp8),
            PrecisionDirective::Mixed => None,
        }
    }

    /// Decide the precision for the next iteration.
    pub fn decide(&mut self, queue_depth: usize, kv_utilization: f64) -> Precision {
        if let Some(f) = self.forced() {
            if f != self.current {
                self.switches += 1;
                self.dwell = self.min_dwell_iters;
                self.current = f;
            }
            match f {
                Precision::Fp16 => self.iters_fp16 += 1,
                Precision::Fp8 => self.iters_fp8 += 1,
            }
            return f;
        }
        let decided = match self.policy {
            PrecisionPolicy::Fp16Only => Precision::Fp16,
            PrecisionPolicy::Fp8Only => Precision::Fp8,
            PrecisionPolicy::Dual => {
                if self.dwell > 0 {
                    self.dwell -= 1;
                    self.current
                } else {
                    let pressure = self.ewma_tpot / self.slo.tpot_target;
                    let spike = self.last_tpot / self.slo.tpot_target;
                    let want = if queue_depth >= QUEUE_PANIC || kv_utilization > 0.90 {
                        Precision::Fp8
                    } else if pressure > HIGH_WATER || spike > SPIKE_WATER {
                        Precision::Fp8
                    } else if pressure < LOW_WATER
                        && spike < LOW_WATER
                        && queue_depth < QUEUE_PANIC
                    {
                        Precision::Fp16
                    } else {
                        self.current // hysteresis band: hold
                    };
                    if want != self.current {
                        self.switches += 1;
                        self.dwell = self.min_dwell_iters;
                        self.current = want;
                    }
                    self.current
                }
            }
        };
        match decided {
            Precision::Fp16 => self.iters_fp16 += 1,
            Precision::Fp8 => self.iters_fp8 += 1,
        }
        decided
    }

    /// Fraction of iterations served at FP16 (the paper reports dual-mode
    /// preserving FP16 for >68% of the time on the Azure trace slice).
    pub fn fp16_fraction(&self) -> f64 {
        let total = self.iters_fp16 + self.iters_fp8;
        if total == 0 {
            1.0
        } else {
            self.iters_fp16 as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> PrecisionController {
        PrecisionController::new(PrecisionPolicy::Dual, SloConfig::default())
    }

    #[test]
    fn fixed_policies_never_switch() {
        let mut c16 = PrecisionController::new(PrecisionPolicy::Fp16Only, SloConfig::default());
        let mut c8 = PrecisionController::new(PrecisionPolicy::Fp8Only, SloConfig::default());
        for _ in 0..100 {
            c16.observe_tpot(1.0); // terrible latency
            assert_eq!(c16.decide(100, 1.0), Precision::Fp16);
            c8.observe_tpot(0.0001);
            assert_eq!(c8.decide(0, 0.0), Precision::Fp8);
        }
        assert_eq!(c16.switches, 0);
        assert_eq!(c8.switches, 0);
    }

    #[test]
    fn escalates_under_latency_pressure() {
        let mut c = ctl();
        for _ in 0..10 {
            c.observe_tpot(0.040); // above 33.3ms SLO
        }
        assert_eq!(c.decide(0, 0.2), Precision::Fp8);
        assert_eq!(c.switches, 1);
    }

    #[test]
    fn recovers_when_load_drops() {
        let mut c = ctl();
        for _ in 0..10 {
            c.observe_tpot(0.040);
        }
        assert_eq!(c.decide(0, 0.2), Precision::Fp8);
        // latency falls well under the low-water mark
        for _ in 0..40 {
            c.observe_tpot(0.010);
        }
        // burn through the dwell period
        let mut last = Precision::Fp8;
        for _ in 0..10 {
            last = c.decide(0, 0.2);
        }
        assert_eq!(last, Precision::Fp16);
        assert_eq!(c.switches, 2);
    }

    #[test]
    fn hysteresis_prevents_flapping() {
        let mut c = ctl();
        // oscillate right around the high-water mark
        let mut switches_seen = Vec::new();
        for i in 0..200 {
            let t = if i % 2 == 0 { 0.0285 } else { 0.0282 }; // ~0.85*SLO
            c.observe_tpot(t);
            c.decide(0, 0.2);
            switches_seen.push(c.switches);
        }
        assert!(
            c.switches <= 4,
            "controller flapped {} times around the threshold",
            c.switches
        );
    }

    #[test]
    fn queue_panic_forces_fp8() {
        let mut c = ctl();
        c.observe_tpot(0.001); // latency is fine
        assert_eq!(c.decide(QUEUE_PANIC, 0.1), Precision::Fp8);
    }

    #[test]
    fn kv_pressure_forces_fp8() {
        let mut c = ctl();
        c.observe_tpot(0.001);
        assert_eq!(c.decide(0, 0.95), Precision::Fp8);
    }

    #[test]
    fn forced_demotion_overrides_policy() {
        // an FP16-only replica demoted by the cluster router serves FP8
        let mut c = PrecisionController::new(PrecisionPolicy::Fp16Only, SloConfig::default());
        assert_eq!(c.decide(0, 0.0), Precision::Fp16);
        c.set_forced(Some(Precision::Fp8));
        for _ in 0..5 {
            assert_eq!(c.decide(0, 0.0), Precision::Fp8);
        }
        assert_eq!(c.switches, 1, "one demotion, no flapping while forced");
        c.set_forced(None);
        assert_eq!(c.decide(0, 0.0), Precision::Fp16);
        assert!(c.iters_fp8 == 5 && c.iters_fp16 >= 2);
    }

    #[test]
    fn forced_release_respects_dwell_under_dual() {
        let mut c = ctl();
        c.observe_tpot(0.001); // no local pressure at all
        c.set_forced(Some(Precision::Fp8));
        assert_eq!(c.decide(0, 0.0), Precision::Fp8);
        c.set_forced(None);
        // dwell keeps the forced mode briefly, then the (calm) signals
        // bring the replica back to FP16 — no instant flap
        let mut saw_fp16 = false;
        for _ in 0..16 {
            c.observe_tpot(0.001);
            if c.decide(0, 0.0) == Precision::Fp16 {
                saw_fp16 = true;
            }
        }
        assert!(saw_fp16, "never recovered to fp16 after release");
        assert!(c.switches <= 2);
    }

    #[test]
    fn directive_rungs_and_stepping() {
        use PrecisionDirective::*;
        assert_eq!(Fp16.rung(), 0);
        assert_eq!(Mixed.rung(), 1);
        assert_eq!(Fp8.rung(), 2);
        // one rung at a time, never skipping Mixed
        assert_eq!(Fp16.step_toward(Fp8), Mixed);
        assert_eq!(Mixed.step_toward(Fp8), Fp8);
        assert_eq!(Fp8.step_toward(Fp16), Mixed);
        assert_eq!(Mixed.step_toward(Fp16), Fp16);
        assert_eq!(Fp8.step_toward(Fp8), Fp8);
        assert_eq!(Mixed.step_toward(Mixed), Mixed);
    }

    #[test]
    fn directive_fp16_quality_locks_a_pressured_dual_controller() {
        // under load a Dual controller wants FP8; a pinned Fp16 directive
        // (the autopilot's quality lock) must win
        let mut c = ctl();
        c.apply_directive(PrecisionDirective::Fp16);
        for _ in 0..20 {
            c.observe_tpot(0.200); // 6x the SLO
            assert_eq!(c.decide(10, 0.99), Precision::Fp16);
        }
        assert_eq!(c.forced(), Some(Precision::Fp16));
        // releasing to Mixed hands control back: pressure drives FP8
        c.apply_directive(PrecisionDirective::Mixed);
        assert_eq!(c.forced(), None);
        let mut last = Precision::Fp16;
        for _ in 0..12 {
            c.observe_tpot(0.200);
            last = c.decide(10, 0.99);
        }
        assert_eq!(last, Precision::Fp8);
    }

    #[test]
    fn set_forced_is_a_directive_shim() {
        let mut c = ctl();
        c.set_forced(Some(Precision::Fp8));
        assert_eq!(c.directive(), PrecisionDirective::Fp8);
        c.set_forced(Some(Precision::Fp16));
        assert_eq!(c.directive(), PrecisionDirective::Fp16);
        c.set_forced(None);
        assert_eq!(c.directive(), PrecisionDirective::Mixed);
    }

    #[test]
    fn fp16_fraction_accounting() {
        let mut c = ctl();
        for _ in 0..10 {
            c.observe_tpot(0.001);
            c.decide(0, 0.0);
        }
        assert_eq!(c.fp16_fraction(), 1.0);
    }
}

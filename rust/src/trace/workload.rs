//! Request workload construction: Poisson arrivals over a rate series,
//! with request shapes either fixed (the paper's fixed 256-in/512-out
//! throughput runs) or sampled (the trace replays).
//!
//! For the real backend, prompts come from the shared task grammar
//! (mirroring python/compile/corpus.py) and are padded with filler task
//! lines to chunk-aligned lengths.

use crate::coordinator::request::Request;
use crate::util::rng::Pcg64;

/// Workload parameters.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    pub seed: u64,
    /// Fixed prompt length (tokens); 0 = sample log-normal.
    pub input_len: usize,
    /// Fixed output budget; 0 = sample log-normal.
    pub output_len: usize,
    /// Align prompt lengths to this multiple (smallest prefill chunk).
    pub chunk_align: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            seed: 7,
            input_len: 256,
            output_len: 512,
            chunk_align: 8,
        }
    }
}

/// Poisson arrival times over a per-second rate series.
pub fn poisson_arrivals(rates: &[f64], seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::new(seed, 991);
    let mut out = Vec::new();
    for (s, &rate) in rates.iter().enumerate() {
        if rate <= 0.0 {
            continue;
        }
        let n = rng.poisson(rate);
        for _ in 0..n {
            out.push(s as f64 + rng.f64());
        }
    }
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out
}

/// Flat `base` req/s rate series with one surge plateau at
/// `base * surge_mult` req/s over `[surge_start, surge_start + surge_len)`
/// seconds, cosine-ramped over 3 s on each edge — the cluster
/// surge-absorption scenario (`repro reproduce cluster`,
/// `examples/cluster_surge.rs`).
pub fn surge_rates(
    base: f64,
    surge_mult: f64,
    seconds: usize,
    surge_start: usize,
    surge_len: usize,
) -> Vec<f64> {
    let ramp = 3.0f64;
    let a = surge_start as f64;
    let b = (surge_start + surge_len) as f64;
    (0..seconds)
        .map(|s| {
            let t = s as f64;
            let w = if t >= a && t < b {
                1.0
            } else if t >= a - ramp && t < a {
                let x = (t - (a - ramp)) / ramp;
                0.5 - 0.5 * (std::f64::consts::PI * x).cos()
            } else if t >= b && t < b + ramp {
                let x = (t - b) / ramp;
                0.5 + 0.5 * (std::f64::consts::PI * x).cos()
            } else {
                0.0
            };
            base * (1.0 + (surge_mult - 1.0) * w)
        })
        .collect()
}

fn sample_len(rng: &mut Pcg64, mean: f64, align: usize, max: usize) -> usize {
    // log-normal with sigma 0.6, clamped
    let mu = mean.ln() - 0.18;
    let v = rng.lognormal(mu, 0.6).round() as usize;
    let v = v.clamp(align, max);
    v.div_ceil(align) * align
}

/// Build the request list for a set of arrival times.
///
/// Prompt token values are synthetic (byte 65 'A' filler) — fine for the
/// sim backend and for throughput runs on the real backend where content
/// does not matter. For accuracy runs use `eval::tasks` prompts instead.
pub fn build_requests(
    arrivals: &[f64],
    cfg: &WorkloadConfig,
    max_context: usize,
) -> Vec<Request> {
    let mut rng = Pcg64::new(cfg.seed, 1203);
    let mut out = Vec::with_capacity(arrivals.len());
    for (i, &t) in arrivals.iter().enumerate() {
        let in_len = if cfg.input_len > 0 {
            cfg.input_len.div_ceil(cfg.chunk_align) * cfg.chunk_align
        } else {
            sample_len(&mut rng, 200.0, cfg.chunk_align, max_context / 2)
        };
        let out_len = if cfg.output_len > 0 {
            cfg.output_len
        } else {
            sample_len(&mut rng, 150.0, 1, max_context / 2)
        };
        let out_len = out_len.min(max_context.saturating_sub(in_len + 2)).max(1);
        let prompt = vec![65i32; in_len];
        out.push(Request::new(i as u64, prompt, out_len, t));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_count_tracks_rates() {
        let rates = vec![10.0; 100];
        let arr = poisson_arrivals(&rates, 3);
        let n = arr.len() as f64;
        assert!((n - 1000.0).abs() < 150.0, "got {n} arrivals for E=1000");
        // sorted and within range
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        assert!(*arr.last().unwrap() < 100.0);
    }

    #[test]
    fn surge_rates_shape() {
        let rates = surge_rates(2.0, 4.0, 60, 20, 10);
        assert_eq!(rates.len(), 60);
        assert!((rates[5] - 2.0).abs() < 1e-9, "flat before the surge");
        assert!((rates[25] - 8.0).abs() < 1e-9, "plateau at base*mult");
        assert!((rates[55] - 2.0).abs() < 1e-9, "flat after the surge");
        // ramps are monotone and bounded
        assert!(rates[18] > 2.0 && rates[18] < 8.0);
        assert!(rates.iter().all(|&r| (2.0..=8.0 + 1e-9).contains(&r)));
    }

    #[test]
    fn fixed_shape_requests() {
        let arr = vec![0.0, 1.0, 2.0];
        let cfg = WorkloadConfig {
            input_len: 250,
            output_len: 512,
            chunk_align: 8,
            ..Default::default()
        };
        let reqs = build_requests(&arr, &cfg, 4096);
        assert_eq!(reqs.len(), 3);
        // 250 -> aligned up to 256
        assert_eq!(reqs[0].prompt.len(), 256);
        assert_eq!(reqs[0].max_new_tokens, 512);
    }

    #[test]
    fn sampled_lengths_aligned_and_bounded() {
        let arr: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let cfg = WorkloadConfig {
            input_len: 0,
            output_len: 0,
            chunk_align: 8,
            seed: 11,
        };
        let reqs = build_requests(&arr, &cfg, 1024);
        for r in &reqs {
            assert_eq!(r.prompt.len() % 8, 0);
            assert!(r.prompt.len() + r.max_new_tokens + 2 <= 1024 + 8);
            assert!(r.max_new_tokens >= 1);
        }
        // lengths vary
        let lens: std::collections::HashSet<usize> =
            reqs.iter().map(|r| r.prompt.len()).collect();
        assert!(lens.len() > 5);
    }
}

//! Synthetic Azure-LLM-inference-trace generator (Figure 1a substitute).
//!
//! The paper reports, for 2024-05-10: per-second request rates spanning
//! 0–100 req/s over the day, a 5.8× min/max ratio within the most
//! variable one-hour window (min 17 / max 98) and 3.2× within the most
//! variable one-minute window (min 31 / max 98). We generate a
//! rate series with the same structure: a diurnal base curve, one busy
//! hour with large swings, minute-scale bursts, and Poisson thinning at
//! one-second granularity — then verify those statistics in tests.

use crate::util::rng::Pcg64;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct AzureTraceConfig {
    pub seed: u64,
    /// Length of the series in seconds (86_400 = one day).
    pub seconds: usize,
    /// Peak of the diurnal base curve, req/s.
    pub peak_rate: f64,
    /// Trough of the diurnal base curve, req/s.
    pub trough_rate: f64,
    /// Start of the high-variability hour (seconds into the series).
    pub busy_hour_start: usize,
    /// Start of the most bursty minute.
    pub busy_minute_start: usize,
}

impl Default for AzureTraceConfig {
    fn default() -> Self {
        AzureTraceConfig {
            seed: 0xA27E,
            seconds: 86_400,
            peak_rate: 88.0,
            trough_rate: 8.0,
            // paper: 14:00–15:00 UTC busiest hour, 18:12 busiest minute
            busy_hour_start: 14 * 3600,
            busy_minute_start: 18 * 3600 + 12 * 60,
        }
    }
}

/// Summary statistics matching the paper's Figure 1a narration.
#[derive(Clone, Copy, Debug)]
pub struct TraceStats {
    pub min_rate: f64,
    pub max_rate: f64,
    /// max/min over the most variable 1-hour window.
    pub worst_hour_ratio: f64,
    /// max/min over the most variable 1-minute window.
    pub worst_minute_ratio: f64,
}

/// Per-second expected request rates for the whole series.
pub fn generate_rate_series(cfg: &AzureTraceConfig) -> Vec<f64> {
    let mut rng = Pcg64::seeded(cfg.seed);
    let mut rates = Vec::with_capacity(cfg.seconds);
    // pre-draw minute-scale burst multipliers (AR(1) for temporal cohesion)
    let minutes = cfg.seconds / 60 + 2;
    let mut burst = vec![1.0f64; minutes];
    for i in 1..minutes {
        let innovation = rng.normal_ms(0.0, 0.22);
        let x: f64 = 0.75 * (burst[i - 1] - 1.0) + innovation;
        burst[i] = (1.0 + x).clamp(0.6, 1.6);
    }
    for s in 0..cfg.seconds {
        let day_phase = s as f64 / cfg.seconds as f64;
        // diurnal: trough near 04:00, peak near 15:00
        let diurnal = 0.5
            - 0.5 * (2.0 * std::f64::consts::PI * (day_phase - 0.625)).cos();
        let base = cfg.trough_rate + (cfg.peak_rate - cfg.trough_rate) * diurnal;

        let in_busy_hour =
            s >= cfg.busy_hour_start && s < cfg.busy_hour_start + 3600;
        let in_busy_minute =
            s >= cfg.busy_minute_start && s < cfg.busy_minute_start + 60;

        let jitter = 1.0 + rng.normal_ms(0.0, 0.05);
        let rate = if in_busy_minute {
            // busiest minute: a sharp intra-minute spike 31 -> 98 (3.2x);
            // shaped directly, no extra multipliers
            let t = (s - cfg.busy_minute_start) as f64 / 60.0;
            (31.0 + (98.0 - 31.0) * (-(t - 0.55).powi(2) / 0.02).exp()) * jitter.clamp(0.97, 1.03)
        } else if in_busy_hour {
            // busy hour: minute-scale swings spanning exactly the paper's
            // 17..98 band (5.8x)
            let m = (s - cfg.busy_hour_start) / 60;
            let swing = ((m as f64 * 0.9).sin() * 0.5 + 0.5).powf(1.3);
            (18.0 + (96.0 - 18.0) * swing) * jitter.clamp(0.95, 1.05)
        } else {
            base * burst[s / 60] * jitter
        };
        rates.push(rate.clamp(0.0, 100.0));
    }
    rates
}

/// The published statistics of a rate series.
pub fn stats(rates: &[f64]) -> TraceStats {
    let min_rate = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_rate = rates.iter().cloned().fold(0.0, f64::max);

    // calendar-aligned windows, as the paper reports them ("the most
    // variable 1-hour window (14:00-15:00 UTC)", "1-minute (18:12-18:13)")
    let window_ratio = |w: usize| -> f64 {
        let mut worst = 1.0f64;
        let mut s = 0;
        while s + w <= rates.len() {
            let win = &rates[s..s + w];
            let mn = win.iter().cloned().fold(f64::INFINITY, f64::min);
            let mx = win.iter().cloned().fold(0.0, f64::max);
            if mn > 0.5 {
                worst = worst.max(mx / mn);
            }
            s += w;
        }
        worst
    };

    TraceStats {
        min_rate,
        max_rate,
        worst_hour_ratio: window_ratio(3600),
        worst_minute_ratio: window_ratio(60),
    }
}

/// Downscale a rate series (the paper's Fig 1b uses 20% of the trace).
pub fn downscale(rates: &[f64], factor: f64) -> Vec<f64> {
    rates.iter().map(|r| r * factor).collect()
}

/// An arbitrary multi-hour window of the day trace: `len_s` seconds of
/// per-second rates from `start_s`, rebased to timestamp 0 and clamped
/// to the series length. This is the `--scale` bench's workload source —
/// a 100+-replica fleet replaying hours of the diurnal curve (bursty
/// minutes included) rather than the few minutes around one spike.
pub fn day_slice(cfg: &AzureTraceConfig, start_s: usize, len_s: usize) -> Vec<f64> {
    let rates = generate_rate_series(cfg);
    let start = start_s.min(rates.len());
    let end = (start + len_s).min(rates.len());
    rates[start..end].to_vec()
}

/// A time-shifted window of the day trace: `len_s` seconds of per-second
/// rates starting `lead_s` seconds *before* `center_s`. The autopilot
/// bench replays the window around the busiest minute (18:12) — a calm
/// lead-in, the 31 → 98 req/s spike, and the drain — downscaled to a
/// small-cluster budget. Returned timestamps are rebased to 0.
pub fn surge_slice(
    cfg: &AzureTraceConfig,
    center_s: usize,
    lead_s: usize,
    len_s: usize,
) -> Vec<f64> {
    day_slice(cfg, center_s.saturating_sub(lead_s), len_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_has_paper_scale_stats() {
        let cfg = AzureTraceConfig::default();
        let rates = generate_rate_series(&cfg);
        assert_eq!(rates.len(), 86_400);
        let st = stats(&rates);
        assert!(st.max_rate <= 100.0);
        assert!(st.min_rate >= 0.0 && st.min_rate < 15.0, "min {}", st.min_rate);
        assert!(
            st.worst_hour_ratio > 4.0,
            "hour ratio {} (paper: 5.8)",
            st.worst_hour_ratio
        );
        assert!(
            st.worst_minute_ratio > 2.5,
            "minute ratio {} (paper: 3.2)",
            st.worst_minute_ratio
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = AzureTraceConfig {
            seconds: 600,
            ..Default::default()
        };
        let a = generate_rate_series(&cfg);
        let b = generate_rate_series(&cfg);
        assert_eq!(a, b);
    }

    #[test]
    fn downscale_scales() {
        let rates = vec![10.0, 50.0];
        assert_eq!(downscale(&rates, 0.2), vec![2.0, 10.0]);
    }

    #[test]
    fn day_slice_windows_and_clamps() {
        let cfg = AzureTraceConfig {
            seconds: 3600,
            ..Default::default()
        };
        let full = generate_rate_series(&cfg);
        // an interior window is exactly the corresponding span, rebased
        let mid = day_slice(&cfg, 600, 1200);
        assert_eq!(mid.len(), 1200);
        assert_eq!(mid[..], full[600..1800]);
        // windows clamp to the series instead of panicking
        let tail = day_slice(&cfg, 3000, 10_000);
        assert_eq!(tail.len(), 600);
        assert_eq!(tail[..], full[3000..]);
        assert!(day_slice(&cfg, 10_000, 100).is_empty());
        // surge_slice is a day_slice with a lead offset
        assert_eq!(
            surge_slice(&cfg, 900, 300, 120),
            day_slice(&cfg, 600, 120)
        );
    }

    #[test]
    fn surge_slice_contains_the_spike() {
        let cfg = AzureTraceConfig::default();
        let slice = surge_slice(&cfg, cfg.busy_minute_start, 60, 180);
        assert_eq!(slice.len(), 180);
        // lead-in is the ambient evening rate; the spike peaks near 98
        let lead_max = slice[..50].iter().cloned().fold(0.0, f64::max);
        let spike_max = slice[60..120].iter().cloned().fold(0.0, f64::max);
        assert!(spike_max > 80.0, "spike missing: {spike_max}");
        assert!(
            spike_max > 2.0 * lead_max,
            "window must ramp: lead {lead_max} spike {spike_max}"
        );
        // deterministic
        assert_eq!(slice, surge_slice(&cfg, cfg.busy_minute_start, 60, 180));
    }
}

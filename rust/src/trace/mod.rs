//! Workload generation: Azure-LLM-inference-like traces and request-size
//! distributions (the data substitute for [2] in the paper; DESIGN.md §2).

pub mod azure;
pub mod workload;

pub use azure::{AzureTraceConfig, TraceStats, day_slice, generate_rate_series};
pub use workload::{WorkloadConfig, build_requests, poisson_arrivals};

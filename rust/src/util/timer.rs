//! Wall-clock timing helpers for the custom bench harness.

use std::time::{Duration, Instant};

/// Time a closure once.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

/// Benchmark result: per-iteration timing statistics in nanoseconds.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {} min {} p50 {} p90 {} ({} iters)",
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p90_ns),
            self.iters
        )
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` repeatedly: first a warmup, then timed iterations until either
/// `max_iters` or `max_time` is reached (whichever first, but at least 5
/// iterations). Returns per-iteration stats.
pub fn bench(warmup: usize, max_iters: usize, max_time: Duration, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(max_iters.min(4096));
    let t_start = Instant::now();
    for i in 0..max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if i >= 4 && t_start.elapsed() > max_time {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    BenchStats {
        iters: n,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        min_ns: samples[0],
        p50_ns: samples[n / 2],
        p90_ns: samples[((n as f64 * 0.9) as usize).min(n - 1)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench(2, 50, Duration::from_millis(200), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.min_ns > 0.0);
        assert!(s.mean_ns >= s.min_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
    }
}

//! Mini property-testing driver (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! from a seeded RNG; on failure it reports the failing case index and a
//! debug rendering of the input, and re-runs with the same seed so
//! failures are exactly reproducible.

use super::rng::Pcg64;

/// Run a property over `cases` generated values. Panics (with context) on
/// the first falsified case.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_0001u64);
    let mut rng = Pcg64::seeded(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' falsified at case {i}/{cases} (seed {seed}):\n  input = {input:?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result` so failures can carry
/// a message.
pub fn check_res<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_0002u64);
    let mut rng = Pcg64::seeded(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' falsified at case {i}/{cases} (seed {seed}):\n  input = {input:?}\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("add-commutes", 50, |r| (r.next_u32(), r.next_u32()), |&(a, b)| {
            count += 1;
            a.wrapping_add(b) == b.wrapping_add(a)
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn failing_property_panics() {
        check("always-false", 10, |r| r.next_u32(), |_| false);
    }
}

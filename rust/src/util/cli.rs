//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus key/value options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got '{v}'")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("serve extra --mode dual --steps=100 --verbose");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("mode"), Some("dual"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_before_positional_not_consumed() {
        // "--verbose serve": 'serve' does not start with --, so it is taken
        // as the value of --verbose. Callers use --verbose at the tail or
        // --verbose=1; test documents the rule.
        let a = parse("--k=v pos --flag");
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.positional, vec!["pos"]);
        assert!(a.flag("flag"));
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("x", 2.5), 2.5);
    }
}

//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` is
//! decoded for the BMP only). Used for the artifact manifest emitted by
//! `python/compile/aot.py` and for machine-readable experiment output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that errors with a useful message.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn utf8_len(first: u8) -> usize {
    match first {
        b if b >= 0xF0 => 4,
        b if b >= 0xE0 => 3,
        b if b >= 0xC0 => 2,
        _ => 1,
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape hex")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x80 => {
                    // ASCII fast path: consume a contiguous run in one go
                    // (a per-char from_utf8 over the remainder would make
                    // parsing O(n^2); see EXPERIMENTS.md §Perf)
                    let start = self.i;
                    while let Some(&b) = self.b.get(self.i) {
                        if b >= 0x80 || b == b'"' || b == b'\\' || b < 0x20 {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
                Some(_) => {
                    // non-ASCII: consume one UTF-8 scalar
                    let len = utf8_len(self.b[self.i]);
                    let end = (self.i + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[self.i..end])
                        .map_err(|_| "invalid utf-8")?;
                    let c = chunk.chars().next().ok_or("invalid utf-8")?;
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{txt}': {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] (got {other:?})")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} (got {other:?})")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\nthere");
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("d"), Some(&Json::Null));
        // serialize and re-parse
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn nested_structures() {
        let src = r#"[{"x": {"y": [[1], [2, 3]]}}, []]"#;
        let v = Json::parse(src).unwrap();
        let x = v.as_arr().unwrap()[0].get("x").unwrap();
        let y = x.get("y").unwrap().as_arr().unwrap();
        assert_eq!(y[1].as_arr().unwrap()[1].as_i64(), Some(3));
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-12", -12.0), ("3.5e2", 350.0), ("1e-3", 0.001)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn integer_output_has_no_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}

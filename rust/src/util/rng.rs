//! PCG64 (XSL-RR 128/64) pseudo-random number generator.
//!
//! Deterministic, seedable, and fast; used everywhere randomness is needed
//! (weight sampling, trace generation, property tests) so that every
//! experiment in EXPERIMENTS.md is exactly reproducible from its seed.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [lo, hi) (hi exclusive). Panics if lo >= hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        let span = hi - lo;
        // Lemire-style rejection-free-enough mapping; bias negligible for
        // span << 2^64, and we additionally reject the biased zone.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.range_u64(0, n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 > 1e-300 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.f64();
        -u.ln() / rate
    }

    /// Poisson sample (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            return v.max(0.0) as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Log-normal sample: exp(Normal(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg64::seeded(9);
        for _ in 0..10_000 {
            let v = r.range_u64(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Pcg64::seeded(13);
        let n = 50_000;
        let lambda = 5.5;
        let s: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}

#[cfg(test)]
mod xlang_tests {
    use super::*;

    /// First outputs for seed 42 / default stream — mirrored verbatim in
    /// python/tests/test_corpus.py so the two languages' generators stay
    /// bit-identical.
    #[test]
    fn cross_language_vector() {
        let mut r = Pcg64::seeded(42);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                5707447046872229490,
                7522330712029359324,
                16568102611872412033,
                560887338126967608,
            ]
        );
    }
}

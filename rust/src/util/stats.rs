//! Latency statistics: exact percentile digest + summary helpers.
//!
//! The serving metrics (TTFT / TPOT p50/p90/p99, Figures 1b, 8, 10) all
//! flow through [`Digest`]. Sample counts in our experiments are modest
//! (≤ ~10^6), so we keep exact samples and sort on query; `Summary`
//! caches the sorted view.

/// Accumulates samples; computes exact order statistics on demand.
///
/// NaN samples are tolerated but never poison a query: they sort last
/// and are dropped (counted in [`Digest::nan_dropped`]) the next time
/// the digest sorts, and the streaming queries ([`Digest::mean`],
/// [`Digest::frac_above`]) skip them.
#[derive(Clone, Debug, Default)]
pub struct Digest {
    samples: Vec<f64>,
    sorted: bool,
    nan_dropped: usize,
}

impl Digest {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn extend_from(&mut self, other: &Digest) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// NaN samples seen and discarded so far (diagnostic counter).
    pub fn nan_dropped(&self) -> usize {
        self.nan_dropped
    }

    fn ensure_sorted(&mut self) {
        if self.sorted {
            return;
        }
        // total order with NaNs last, then drop them: a poisoned sample
        // must degrade one data point, not panic every percentile query
        self.samples
            .sort_unstable_by(|a, b| match (a.is_nan(), b.is_nan()) {
                (false, false) => a.partial_cmp(b).expect("both non-NaN"),
                (false, true) => std::cmp::Ordering::Less,
                (true, false) => std::cmp::Ordering::Greater,
                (true, true) => std::cmp::Ordering::Equal,
            });
        while self.samples.last().is_some_and(|v| v.is_nan()) {
            self.samples.pop();
            self.nan_dropped += 1;
        }
        self.sorted = true;
    }

    /// Exact percentile by linear interpolation; `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        self.ensure_sorted();
        percentile_sorted(&self.samples, q)
    }

    pub fn mean(&self) -> f64 {
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for &v in &self.samples {
            if !v.is_nan() {
                sum += v;
                n += 1;
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.last().copied().unwrap_or(f64::NAN)
    }

    /// Fraction of (non-NaN) samples strictly greater than `threshold`.
    pub fn frac_above(&self, threshold: f64) -> f64 {
        let n = self.samples.iter().filter(|v| !v.is_nan()).count();
        if n == 0 {
            return 0.0;
        }
        self.samples.iter().filter(|&&v| v > threshold).count() as f64 / n as f64
    }

    pub fn summary(&mut self) -> Summary {
        self.ensure_sorted(); // drop NaNs first so count/mean/order agree
        Summary {
            count: self.len(),
            mean: self.mean(),
            min: self.min(),
            p50: self.percentile(50.0),
            p90: self.percentile(90.0),
            p99: self.percentile(99.0),
            max: self.max(),
        }
    }
}

/// A frozen view of a digest's headline numbers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} p50={:.3} p90={:.3} p99={:.3} max={:.3}",
            self.count, self.mean, self.p50, self.p90, self.p99, self.max
        )
    }
}

/// Exact percentile of an already-**sorted** slice by linear
/// interpolation; `q` clamps to [0, 100] (an out-of-range rank is a
/// caller bug worth a min/max answer, not a panic in the metrics path);
/// NaN when empty or when `q` is NaN. The single percentile definition
/// in the crate — [`Digest::percentile`] and the autopilot's
/// sliding-window SLO tracker both delegate here, so reported and
/// control-loop percentiles can never drift apart.
pub fn percentile_sorted(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() || q.is_nan() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 100.0);
    let n = xs.len();
    if n == 1 {
        return xs[0];
    }
    let pos = q / 100.0 * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    xs[lo] * (1.0 - frac) + xs[hi] * frac
}

/// Mean of a slice (NaN if empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_exact() {
        let mut d = Digest::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            d.add(v);
        }
        assert_eq!(d.percentile(0.0), 1.0);
        assert_eq!(d.percentile(50.0), 3.0);
        assert_eq!(d.percentile(100.0), 5.0);
        assert!((d.percentile(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut d = Digest::new();
        d.add(0.0);
        d.add(10.0);
        assert!((d.percentile(90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let mut d = Digest::new();
        for i in 1..=100 {
            d.add(i as f64);
        }
        let s = d.summary();
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p90 - 90.1).abs() < 0.2);
    }

    #[test]
    fn frac_above_counts() {
        let mut d = Digest::new();
        for i in 0..10 {
            d.add(i as f64);
        }
        assert!((d.frac_above(6.5) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn helpers() {
        assert!((mean(&[2.0, 4.0]) - 3.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!(stddev(&[1.0, 1.0, 1.0]) < 1e-12);
    }

    #[test]
    fn empty_digest_never_panics() {
        let mut d = Digest::new();
        assert!(d.percentile(50.0).is_nan());
        assert!(d.mean().is_nan());
        assert!(d.min().is_nan() && d.max().is_nan());
        assert_eq!(d.frac_above(0.0), 0.0);
        let s = d.summary();
        assert_eq!(s.count, 0);
        assert!(s.p99.is_nan());
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let mut d = Digest::new();
        d.add(3.5);
        for q in [0.0, 37.0, 50.0, 100.0] {
            assert_eq!(d.percentile(q), 3.5);
        }
        let s = d.summary();
        assert_eq!((s.min, s.max, s.mean), (3.5, 3.5, 3.5));
    }

    #[test]
    fn nan_samples_drop_instead_of_panicking() {
        let mut d = Digest::new();
        for v in [2.0, f64::NAN, 1.0, 3.0, f64::NAN] {
            d.add(v);
        }
        // streaming queries skip NaNs even before a sort happens
        assert!((d.mean() - 2.0).abs() < 1e-12);
        assert!((d.frac_above(1.5) - 2.0 / 3.0).abs() < 1e-12);
        // ordered queries sort NaNs last and drop them, with a count
        assert_eq!(d.percentile(50.0), 2.0);
        assert_eq!(d.len(), 3, "NaNs no longer stored after sorting");
        assert_eq!(d.nan_dropped(), 2);
        assert_eq!(d.max(), 3.0, "max is the largest real sample");
        let s = d.summary();
        assert_eq!(s.count, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn out_of_range_q_clamps_to_min_max() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&xs, -10.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 170.0), 3.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 3.0);
        assert!(percentile_sorted(&xs, f64::NAN).is_nan());
        let mut d = Digest::new();
        d.add(5.0);
        d.add(7.0);
        assert_eq!(d.percentile(-1.0), 5.0);
        assert_eq!(d.percentile(101.0), 7.0);
    }
}

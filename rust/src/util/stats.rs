//! Latency statistics: bounded-memory percentile digest + summary helpers.
//!
//! The serving metrics (TTFT / TPOT p50/p90/p99, Figures 1b, 8, 10) all
//! flow through [`Digest`]. Small runs (≤ [`SAMPLE_CAP`] samples) keep
//! exact samples and sort on query — every percentile is exact, which
//! the metrics tests rely on. Past the cap the digest folds into a
//! **fixed-size log-bucketed histogram** (32 sub-buckets per power of
//! two, ~20 KB regardless of sample count), so a multi-hour
//! `--scale` run with millions of requests costs constant memory per
//! metric. Sketched percentiles carry a documented quantization error:
//! the reported value is the midpoint of a bucket spanning a 2^(1/32)
//! ratio, i.e. within ~2.2% relative of the exact answer (count, mean,
//! min and max stay exact in both modes). Sketches merge bucket-wise,
//! deterministically — same inputs, same bytes out.

/// Exact samples are kept up to this many; the digest then switches to
/// the bounded sketch for the rest of its life.
pub const SAMPLE_CAP: usize = 4096;

const SUB_BITS: u32 = 5;
/// Sub-buckets per octave (power of two): 2^[`SUB_BITS`].
const SUB: usize = 1 << SUB_BITS;
/// Smallest bucketed exponent: values below 2^-40 (≈ 9e-13 — well under
/// a picosecond for latency metrics) land in the underflow bucket.
const EXP_MIN: i32 = -40;
/// Largest bucketed exponent: values ≥ 2^40 (≈ 1.1e12) overflow.
const EXP_MAX: i32 = 39;
const N_BUCKETS: usize = ((EXP_MAX - EXP_MIN + 1) as usize) * SUB;

/// The fixed-size streaming histogram backing large digests.
#[derive(Clone, Debug)]
struct Sketch {
    buckets: Vec<u64>,
    /// Values < 2^[`EXP_MIN`], including zeros and negatives.
    underflow: u64,
    /// Values ≥ 2^([`EXP_MAX`]+1).
    overflow: u64,
}

impl Sketch {
    fn new() -> Sketch {
        Sketch {
            buckets: vec![0; N_BUCKETS],
            underflow: 0,
            overflow: 0,
        }
    }

    /// Exact power of two via the bit pattern (no libm, deterministic).
    fn pow2(e: i32) -> f64 {
        debug_assert!((-1022..=1023).contains(&e));
        f64::from_bits(((e + 1023) as u64) << 52)
    }

    fn add(&mut self, v: f64) {
        debug_assert!(!v.is_nan());
        if v < Self::pow2(EXP_MIN) {
            // zeros, negatives, subnormals, tiny values
            self.underflow += 1;
            return;
        }
        let bits = v.to_bits();
        let e = ((bits >> 52) & 0x7ff) as i32 - 1023;
        if e > EXP_MAX {
            self.overflow += 1;
            return;
        }
        let j = ((bits >> (52 - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        self.buckets[(e - EXP_MIN) as usize * SUB + j] += 1;
    }

    /// Midpoint representative of bucket `i` (within 2^(1/32) of every
    /// value the bucket holds — the documented quantization error).
    fn rep(i: usize) -> f64 {
        let e = EXP_MIN + (i / SUB) as i32;
        let j = i % SUB;
        Self::pow2(e) * (1.0 + (j as f64 + 0.5) / SUB as f64)
    }

    fn merge(&mut self, other: &Sketch) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
    }
}

/// Accumulates samples; computes order statistics on demand — exact up
/// to [`SAMPLE_CAP`] samples, then within the sketch's quantization
/// error (see module docs).
///
/// NaN samples are tolerated but never poison a query: in exact mode
/// they sort last and are dropped (counted in [`Digest::nan_dropped`])
/// the next time the digest sorts; in sketch mode they are dropped on
/// arrival.
#[derive(Clone, Debug)]
pub struct Digest {
    samples: Vec<f64>,
    sorted: bool,
    nan_dropped: usize,
    sketch: Option<Box<Sketch>>,
    // running aggregates, authoritative in sketch mode (exact mode
    // derives them from the samples)
    count: usize,
    sum: f64,
    lo: f64,
    hi: f64,
}

impl Default for Digest {
    fn default() -> Self {
        Digest {
            samples: Vec::new(),
            sorted: false,
            nan_dropped: 0,
            sketch: None,
            count: 0,
            sum: 0.0,
            lo: f64::INFINITY,
            hi: f64::NEG_INFINITY,
        }
    }
}

impl Digest {
    pub fn new() -> Self {
        Self::default()
    }

    /// Has this digest folded into the bounded sketch?
    pub fn is_sketched(&self) -> bool {
        self.sketch.is_some()
    }

    pub fn add(&mut self, v: f64) {
        if self.sketch.is_some() {
            self.absorb(v);
        } else {
            self.samples.push(v);
            self.sorted = false;
            if self.samples.len() > SAMPLE_CAP {
                self.fold_into_sketch();
            }
        }
    }

    /// Fold one value into the sketch-mode aggregates.
    fn absorb(&mut self, v: f64) {
        if v.is_nan() {
            self.nan_dropped += 1;
            return;
        }
        self.count += 1;
        self.sum += v;
        self.lo = self.lo.min(v);
        self.hi = self.hi.max(v);
        self.sketch.as_mut().expect("sketch mode").add(v);
    }

    fn fold_into_sketch(&mut self) {
        self.sketch = Some(Box::new(Sketch::new()));
        let samples = std::mem::take(&mut self.samples);
        for v in samples {
            self.absorb(v);
        }
        self.sorted = false;
    }

    pub fn extend_from(&mut self, other: &Digest) {
        if self.sketch.is_none()
            && other.sketch.is_none()
            && self.samples.len() + other.samples.len() <= SAMPLE_CAP
        {
            self.samples.extend_from_slice(&other.samples);
            self.sorted = false;
            return;
        }
        if self.sketch.is_none() {
            self.fold_into_sketch();
        }
        match &other.sketch {
            Some(sk) => {
                self.sketch.as_mut().expect("folded above").merge(sk);
                self.count += other.count;
                self.sum += other.sum;
                self.lo = self.lo.min(other.lo);
                self.hi = self.hi.max(other.hi);
                self.nan_dropped += other.nan_dropped;
            }
            None => {
                for &v in &other.samples {
                    self.absorb(v);
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        if self.sketch.is_some() {
            self.count
        } else {
            self.samples.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// NaN samples seen and discarded so far (diagnostic counter).
    pub fn nan_dropped(&self) -> usize {
        self.nan_dropped
    }

    fn ensure_sorted(&mut self) {
        debug_assert!(self.sketch.is_none(), "sketch mode never sorts");
        if self.sorted {
            return;
        }
        // total order with NaNs last, then drop them: a poisoned sample
        // must degrade one data point, not panic every percentile query
        self.nan_dropped += sort_drop_nans(&mut self.samples);
        self.sorted = true;
    }

    /// Percentile by linear interpolation, `q` in [0, 100] — exact in
    /// sample mode, bucket-midpoint (nearest rank) in sketch mode.
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.sketch.is_some() {
            return self.sketch_percentile(q);
        }
        self.ensure_sorted();
        percentile_sorted(&self.samples, q)
    }

    fn sketch_percentile(&self, q: f64) -> f64 {
        let sk = self.sketch.as_ref().expect("sketch mode");
        if self.count == 0 || q.is_nan() {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 100.0);
        // the extremes are tracked exactly; don't quantize them
        if self.count == 1 || q == 0.0 {
            return self.lo;
        }
        if q == 100.0 {
            return self.hi;
        }
        let target = (q / 100.0 * (self.count - 1) as f64).round() as u64;
        let mut cum = sk.underflow;
        if target < cum {
            // underflow values are the smallest; min is exact
            return self.lo;
        }
        for (i, &c) in sk.buckets.iter().enumerate() {
            cum += c;
            if target < cum {
                return Sketch::rep(i).clamp(self.lo, self.hi);
            }
        }
        self.hi
    }

    pub fn mean(&self) -> f64 {
        if self.sketch.is_some() {
            return if self.count == 0 {
                f64::NAN
            } else {
                self.sum / self.count as f64
            };
        }
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for &v in &self.samples {
            if !v.is_nan() {
                sum += v;
                n += 1;
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }

    pub fn min(&mut self) -> f64 {
        if self.sketch.is_some() {
            return if self.count == 0 { f64::NAN } else { self.lo };
        }
        self.ensure_sorted();
        self.samples.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&mut self) -> f64 {
        if self.sketch.is_some() {
            return if self.count == 0 { f64::NAN } else { self.hi };
        }
        self.ensure_sorted();
        self.samples.last().copied().unwrap_or(f64::NAN)
    }

    /// Fraction of (non-NaN) samples strictly greater than `threshold`
    /// — exact in sample mode, bucket-resolution in sketch mode.
    pub fn frac_above(&self, threshold: f64) -> f64 {
        if let Some(sk) = &self.sketch {
            if self.count == 0 {
                return 0.0;
            }
            let mut above = if self.lo > threshold { sk.underflow } else { 0 };
            for (i, &c) in sk.buckets.iter().enumerate() {
                if c > 0 && Sketch::rep(i).clamp(self.lo, self.hi) > threshold {
                    above += c;
                }
            }
            if self.hi > threshold {
                above += sk.overflow;
            }
            return above as f64 / self.count as f64;
        }
        let n = self.samples.iter().filter(|v| !v.is_nan()).count();
        if n == 0 {
            return 0.0;
        }
        self.samples.iter().filter(|&&v| v > threshold).count() as f64 / n as f64
    }

    pub fn summary(&mut self) -> Summary {
        if self.sketch.is_none() {
            self.ensure_sorted(); // drop NaNs first so count/mean/order agree
        }
        Summary {
            count: self.len(),
            mean: self.mean(),
            min: self.min(),
            p50: self.percentile(50.0),
            p90: self.percentile(90.0),
            p99: self.percentile(99.0),
            max: self.max(),
        }
    }
}

/// A frozen view of a digest's headline numbers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} p50={:.3} p90={:.3} p99={:.3} max={:.3}",
            self.count, self.mean, self.p50, self.p90, self.p99, self.max
        )
    }
}

/// Sort `xs` in place under a total order that puts NaNs last, then pop
/// the trailing NaNs; returns how many were dropped. The crate's single
/// NaN-hardening primitive for order statistics: [`Digest`] and the
/// autopilot's sliding-window SLO tracker both route here, so a
/// poisoned latency sample degrades one data point instead of panicking
/// a control loop mid-flight.
pub fn sort_drop_nans(xs: &mut Vec<f64>) -> usize {
    xs.sort_unstable_by(|a, b| match (a.is_nan(), b.is_nan()) {
        (false, false) => a.partial_cmp(b).expect("both non-NaN"),
        (false, true) => std::cmp::Ordering::Less,
        (true, false) => std::cmp::Ordering::Greater,
        (true, true) => std::cmp::Ordering::Equal,
    });
    let mut dropped = 0;
    while xs.last().is_some_and(|v| v.is_nan()) {
        xs.pop();
        dropped += 1;
    }
    dropped
}

/// Exact percentile of an already-**sorted** slice by linear
/// interpolation; `q` clamps to [0, 100] (an out-of-range rank is a
/// caller bug worth a min/max answer, not a panic in the metrics path);
/// NaN when empty or when `q` is NaN. The single percentile definition
/// in the crate — [`Digest::percentile`] and the autopilot's
/// sliding-window SLO tracker both delegate here, so reported and
/// control-loop percentiles can never drift apart.
pub fn percentile_sorted(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() || q.is_nan() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 100.0);
    let n = xs.len();
    if n == 1 {
        return xs[0];
    }
    let pos = q / 100.0 * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    xs[lo] * (1.0 - frac) + xs[hi] * frac
}

/// Mean of a slice (NaN if empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_exact() {
        let mut d = Digest::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            d.add(v);
        }
        assert_eq!(d.percentile(0.0), 1.0);
        assert_eq!(d.percentile(50.0), 3.0);
        assert_eq!(d.percentile(100.0), 5.0);
        assert!((d.percentile(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut d = Digest::new();
        d.add(0.0);
        d.add(10.0);
        assert!((d.percentile(90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let mut d = Digest::new();
        for i in 1..=100 {
            d.add(i as f64);
        }
        let s = d.summary();
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p90 - 90.1).abs() < 0.2);
    }

    #[test]
    fn frac_above_counts() {
        let mut d = Digest::new();
        for i in 0..10 {
            d.add(i as f64);
        }
        assert!((d.frac_above(6.5) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn helpers() {
        assert!((mean(&[2.0, 4.0]) - 3.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!(stddev(&[1.0, 1.0, 1.0]) < 1e-12);
    }

    #[test]
    fn empty_digest_never_panics() {
        let mut d = Digest::new();
        assert!(d.percentile(50.0).is_nan());
        assert!(d.mean().is_nan());
        assert!(d.min().is_nan() && d.max().is_nan());
        assert_eq!(d.frac_above(0.0), 0.0);
        let s = d.summary();
        assert_eq!(s.count, 0);
        assert!(s.p99.is_nan());
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let mut d = Digest::new();
        d.add(3.5);
        for q in [0.0, 37.0, 50.0, 100.0] {
            assert_eq!(d.percentile(q), 3.5);
        }
        let s = d.summary();
        assert_eq!((s.min, s.max, s.mean), (3.5, 3.5, 3.5));
    }

    #[test]
    fn nan_samples_drop_instead_of_panicking() {
        let mut d = Digest::new();
        for v in [2.0, f64::NAN, 1.0, 3.0, f64::NAN] {
            d.add(v);
        }
        // streaming queries skip NaNs even before a sort happens
        assert!((d.mean() - 2.0).abs() < 1e-12);
        assert!((d.frac_above(1.5) - 2.0 / 3.0).abs() < 1e-12);
        // ordered queries sort NaNs last and drop them, with a count
        assert_eq!(d.percentile(50.0), 2.0);
        assert_eq!(d.len(), 3, "NaNs no longer stored after sorting");
        assert_eq!(d.nan_dropped(), 2);
        assert_eq!(d.max(), 3.0, "max is the largest real sample");
        let s = d.summary();
        assert_eq!(s.count, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sort_drop_nans_sorts_and_counts() {
        let mut xs = vec![f64::NAN, 3.0, 1.0, f64::NAN, 2.0];
        assert_eq!(sort_drop_nans(&mut xs), 2);
        assert_eq!(xs, vec![1.0, 2.0, 3.0]);
        let mut clean = vec![5.0, 4.0];
        assert_eq!(sort_drop_nans(&mut clean), 0);
        assert_eq!(clean, vec![4.0, 5.0]);
        let mut all_nan = vec![f64::NAN, f64::NAN];
        assert_eq!(sort_drop_nans(&mut all_nan), 2);
        assert!(all_nan.is_empty());
    }

    #[test]
    fn out_of_range_q_clamps_to_min_max() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&xs, -10.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 170.0), 3.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 3.0);
        assert!(percentile_sorted(&xs, f64::NAN).is_nan());
        let mut d = Digest::new();
        d.add(5.0);
        d.add(7.0);
        assert_eq!(d.percentile(-1.0), 5.0);
        assert_eq!(d.percentile(101.0), 7.0);
    }

    // ---- bounded (sketch) mode --------------------------------------

    /// Deterministic log-uniform-ish positive values for sketch tests.
    fn synth(n: usize, seed: u64) -> Vec<f64> {
        let mut x = seed.max(1);
        (0..n)
            .map(|_| {
                // xorshift64*
                x ^= x >> 12;
                x ^= x << 25;
                x ^= x >> 27;
                let u = (x.wrapping_mul(0x2545F4914F6CDD1D) >> 11) as f64 / (1u64 << 53) as f64;
                // span ~5 decades: 1e-4 .. 10
                1e-4 * (10f64).powf(u * 5.0)
            })
            .collect()
    }

    #[test]
    fn digest_stays_exact_up_to_the_cap() {
        let mut d = Digest::new();
        for i in 0..SAMPLE_CAP {
            d.add(i as f64);
        }
        assert!(!d.is_sketched(), "exactly at the cap stays exact");
        d.add(0.5);
        assert!(d.is_sketched(), "one past the cap folds");
        assert_eq!(d.len(), SAMPLE_CAP + 1);
    }

    #[test]
    fn sketch_percentiles_within_documented_error() {
        let vals = synth(50_000, 42);
        let mut d = Digest::new();
        for &v in &vals {
            d.add(v);
        }
        assert!(d.is_sketched());
        assert_eq!(d.len(), vals.len());

        let mut sorted = vals.clone();
        sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [1.0, 10.0, 50.0, 90.0, 99.0] {
            let exact = percentile_sorted(&sorted, q);
            let got = d.percentile(q);
            let rel = (got - exact).abs() / exact;
            assert!(
                rel < 0.025,
                "p{q}: sketch {got} vs exact {exact} (rel {rel:.4})"
            );
        }
        // count/mean/min/max stay exact
        let exact_mean = vals.iter().sum::<f64>() / vals.len() as f64;
        assert!((d.mean() - exact_mean).abs() / exact_mean < 1e-12);
        assert_eq!(d.min(), sorted[0]);
        assert_eq!(d.max(), *sorted.last().unwrap());
        // extremes are exact, interior percentiles clamp into range
        assert_eq!(d.percentile(0.0), sorted[0]);
        assert_eq!(d.percentile(100.0), *sorted.last().unwrap());
    }

    #[test]
    fn sketch_merge_is_bucketwise_and_deterministic() {
        let a_vals = synth(10_000, 1);
        let b_vals = synth(10_000, 2);
        let build = |vals: &[f64]| {
            let mut d = Digest::new();
            for &v in vals {
                d.add(v);
            }
            d
        };
        // merged digest == digest of concatenated stream (same buckets)
        let mut merged = build(&a_vals);
        merged.extend_from(&build(&b_vals));
        let mut whole = build(&a_vals);
        for &v in &b_vals {
            whole.add(v);
        }
        assert_eq!(merged.len(), whole.len());
        for q in [10.0, 50.0, 99.0] {
            assert_eq!(merged.percentile(q), whole.percentile(q), "p{q}");
        }
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
    }

    #[test]
    fn exact_digest_merging_into_sketched_folds() {
        let mut big = Digest::new();
        for &v in &synth(20_000, 7) {
            big.add(v);
        }
        let mut small = Digest::new();
        for v in [0.25, 0.5, f64::NAN] {
            small.add(v);
        }
        let n = big.len();
        big.extend_from(&small);
        assert_eq!(big.len(), n + 2, "NaN dropped on absorption");
        assert_eq!(big.nan_dropped(), 1);
        assert!(big.min() <= 0.25, "absorbed samples count toward min");
    }

    #[test]
    fn sketch_zero_and_negative_values_underflow_to_exact_min() {
        let mut d = Digest::new();
        for i in 0..(SAMPLE_CAP + 100) {
            d.add(if i % 2 == 0 { 0.0 } else { -1.5 });
        }
        assert!(d.is_sketched());
        assert_eq!(d.min(), -1.5);
        assert_eq!(d.max(), 0.0);
        assert_eq!(d.percentile(10.0), -1.5, "underflow reports the exact min");
    }
}

//! Std-only utility layer.
//!
//! The build environment is offline with a minimal crate cache, so the
//! usual ecosystem crates (rand, serde, clap, criterion, proptest) are not
//! available. This module supplies the small, well-tested subset we need.

pub mod rng;
pub mod stats;
pub mod json;
pub mod cli;
pub mod prop;
pub mod timer;

pub use rng::Pcg64;
pub use stats::{Digest, Summary};

//! `repro` — the NestedFP command-line entry point.
//!
//! ```text
//! repro reproduce <exp>      regenerate a paper table/figure
//!                            exp: table1|table2|table3|fig1a|fig1b|fig3|
//!                                 fig7a|fig7b|fig8|fig9|fig10|fig13|
//!                                 gemm|attention|cluster|kvcache|autopilot|
//!                                 morph|parallelism|all
//!        [--artifacts DIR]   artifact directory (default: artifacts)
//!        [--eval-n N]        eval examples per task for table1 (default 24)
//!        [--json FILE]       also write the reports as machine-readable
//!                            JSON (perf-trajectory tracking across PRs),
//!                            including the flat telemetry counter dump
//!        [--trace FILE]      export a Chrome-trace/Perfetto JSON timeline
//!                            of every experiment (virtual-clock spans per
//!                            replica + control plane; open in
//!                            ui.perfetto.dev)
//!        [--quick]           gemm/attention/autopilot/morph/parallelism/
//!                            cluster/kvcache: reduced scenario, CI budget
//!        [--scale]           cluster only: the discrete-event scale arm
//!                            (100+ replicas over a multi-hour Azure day
//!                            slice, per-event accounting; --quick keeps
//!                            the replica floor on a 15-min slice)
//!        [--update-trajectory]
//!                            gemm: rewrite GEMM_BENCH.json from this
//!                            run's measured GFLOP/s; attention: rewrite
//!                            ATTN_BENCH.json from this run's measured
//!                            effective bandwidth
//! repro serve                TCP serving front-end on the real backend
//!        [--addr HOST:PORT]  default 127.0.0.1:7171
//!        [--mode dual|fp16|fp8]
//!        [--replicas N]      engine replicas behind the front door (default 1)
//!        [--autopilot]       wall-clock autopilot monitor: jobs-in-flight
//!                            pressure drives FP16/Mixed/FP8 directives
//! repro analyze              weight-store + applicability summary
//! repro analyze trace FILE   validate an exported trace (JSON shape,
//!                            span balance, timestamp order)
//! repro gemm --m M --n N --k K [--format fp16|nested16|nested8|fp8]
//!                            one autotuned gpusim query (debugging)
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use nestedfp::bench::gemm::{self as gemmbench, BenchOpts};
use nestedfp::bench::{
    attention as attnbench, autopilot as autopilotbench, cluster, fig1, fig3, fig7, fig8,
    kvcache, morph as morphbench, parallelism as parallelismbench, report::Report, table1,
    table3,
};
use nestedfp::coordinator::autopilot::{Autopilot, AutopilotConfig};
use nestedfp::coordinator::backend::{ModeMap, RealBackend};
use nestedfp::coordinator::engine::{Engine, EngineConfig};
use nestedfp::coordinator::precision::PrecisionPolicy;
use nestedfp::coordinator::server;
use nestedfp::gpusim::{self, GemmQuery, OptLevel, WeightFormat};
use nestedfp::runtime::ModelRuntime;
use nestedfp::telemetry::{export, registry, trace};
use nestedfp::util::cli::Args;
use nestedfp::{log_info, log_warn};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "reproduce" => cmd_reproduce(&args),
        "serve" => cmd_serve(&args),
        "analyze" => cmd_analyze(&args),
        "gemm" => cmd_gemm(&args),
        _ => {
            eprintln!(
                "nestedfp repro — usage:\n  \
                 repro reproduce <table1|table2|table3|fig1a|fig1b|fig3|fig7a|fig7b|fig8|fig9|fig10|fig13|gemm|attention|cluster|kvcache|autopilot|morph|parallelism|all> [--json FILE] [--quick] [--scale]\n  \
                 repro serve [--addr HOST:PORT] [--mode dual|fp16|fp8] [--replicas N] [--autopilot]\n  \
                 repro analyze\n  \
                 repro gemm --m M --n N --k K [--format ...]"
            );
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn print_reports(reports: Vec<Report>) {
    for r in reports {
        println!("{}", r.render());
    }
}

/// Run one experiment and return its reports (printed by the caller, and
/// optionally serialized with `--json`).
fn run_one(
    exp: &str,
    dir: &Path,
    eval_n: usize,
    gemm_opts: BenchOpts,
) -> anyhow::Result<Vec<Report>> {
    Ok(match exp {
        "attention" => attnbench::attention_sweep(&gemm_opts)?,
        "autopilot" => autopilotbench::autopilot_surge(gemm_opts.quick)?,
        "morph" => morphbench::morph_frontier(gemm_opts.quick)?,
        "parallelism" => parallelismbench::parallelism_surge(gemm_opts.quick)?,
        "table1" | "table2" => vec![table1::table12(dir, eval_n)?, table1::table2_weights(dir)?],
        "table3" => vec![table3::table3()],
        "fig1a" => vec![fig1::fig1a()],
        "fig1b" => vec![fig1::fig1b()?],
        "fig3" => vec![fig3::fig3a(dir)?, fig3::fig3b(dir)?],
        "fig7a" => fig7::fig7a(),
        "fig7b" => vec![fig7::fig7b()],
        "fig8" => fig8::fig8()?,
        "fig9" => vec![fig7::fig9()],
        "fig10" => fig8::fig10()?,
        "fig13" => vec![fig7::fig13()],
        "gemm" => gemmbench::gemm_bench(&gemm_opts)?,
        "cluster" => {
            if gemm_opts.scale {
                vec![cluster::cluster_scale(gemm_opts.quick)?]
            } else {
                vec![cluster::cluster_scaling()?]
            }
        }
        "kvcache" => vec![kvcache::kvcache_pressure(gemm_opts.quick)?, kvcache::codec_error()],
        other => anyhow::bail!("unknown experiment '{other}'"),
    })
}

/// Serialize collected experiment reports as JSON for perf-trajectory
/// tooling (stable schema; rows are strings exactly as printed), plus
/// the flat telemetry counter dump accumulated in the global registry.
/// Success messaging is the caller's job — it knows whether the run
/// was complete or a bench failed partway.
fn write_json(path: &str, experiments: &[(String, Vec<Report>)]) -> anyhow::Result<()> {
    use nestedfp::util::json::Json;
    let exps: Vec<Json> = experiments
        .iter()
        .map(|(name, reports)| {
            let mut obj = BTreeMap::new();
            obj.insert("name".to_string(), Json::Str(name.clone()));
            obj.insert(
                "reports".to_string(),
                Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
            );
            Json::Obj(obj)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert(
        "schema".to_string(),
        Json::Str("nestedfp/bench-reports@1".to_string()),
    );
    root.insert("experiments".to_string(), Json::Arr(exps));
    root.insert("counters".to_string(), registry::global_snapshot().to_json());
    std::fs::write(path, Json::Obj(root).to_string() + "\n")?;
    Ok(())
}

fn cmd_reproduce(args: &Args) -> i32 {
    let exp = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let dir = artifacts_dir(args);
    let eval_n = args.get_usize("eval-n", 24);
    let gemm_opts = BenchOpts {
        quick: args.flag("quick"),
        update_trajectory: args.flag("update-trajectory"),
        scale: args.flag("scale"),
    };
    // every invocation starts with a clean counter registry; a --trace
    // flag additionally installs the span tracer for the whole run
    registry::reset_global();
    if args.get("trace").is_some() {
        trace::install(trace::DEFAULT_CAP);
    }
    let mut collected: Vec<(String, Vec<Report>)> = Vec::new();
    let mut run_and_print = |e: &str| -> anyhow::Result<()> {
        let reports =
            nestedfp::bench::report::traced(e, || run_one(e, &dir, eval_n, gemm_opts))?;
        collected.push((e.to_string(), reports.clone()));
        print_reports(reports);
        Ok(())
    };
    let result = if exp == "all" {
        let mut r = Ok(());
        for e in [
            "fig1a", "fig1b", "fig3", "fig7a", "fig7b", "fig9", "fig13", "fig8", "fig10",
            "gemm", "attention", "cluster", "kvcache", "autopilot", "morph", "parallelism",
            "table3", "table1",
        ] {
            log_info!("[reproduce] running {e} ...");
            r = run_and_print(e);
            if r.is_err() {
                break;
            }
        }
        r
    } else {
        run_and_print(exp)
    };
    if let Some(path) = args.get("trace") {
        match nestedfp::bench::report::export_trace(path) {
            Ok(Some(n)) => log_info!("[reproduce] wrote trace ({n} events) to {path}"),
            Ok(None) => {}
            Err(e) => {
                log_warn!("reproduce --trace {path}: {e:#}");
                return 1;
            }
        }
    }
    if let Some(path) = args.get("json") {
        if collected.is_empty() {
            log_warn!("[reproduce] --json {path}: nothing written (no experiment completed)");
        } else if let Err(e) = write_json(path, &collected) {
            log_warn!("reproduce --json {path}: {e:#}");
            return 1;
        } else if result.is_ok() {
            log_info!("[reproduce] wrote JSON reports to {path}");
        } else {
            // a bench failed after earlier ones succeeded: the file holds
            // only those, so don't claim a complete run
            log_warn!(
                "[reproduce] wrote PARTIAL JSON reports to {path} \
                 ({} experiment(s) completed before the failure)",
                collected.len()
            );
        }
    }
    match result {
        Ok(()) => 0,
        Err(e) => {
            log_warn!("reproduce {exp}: {e:#}");
            1
        }
    }
}

/// The live-serving control loop: every 250 ms of wall time, turn each
/// replica's jobs-in-flight count into a pressure score and run the same
/// [`Autopilot::control_at`] law the virtual-clock cluster uses; ship the
/// resulting FP16/Mixed/FP8 directives to the engine workers. (Workers
/// apply the latest directive between batches — coarse, but the law,
/// dwell discipline, and ladder are exactly the tested ones.)
fn spawn_autopilot_monitor(
    frontend: std::sync::Arc<server::ClusterFrontend>,
    directive_senders: Vec<std::sync::mpsc::Sender<nestedfp::coordinator::PrecisionDirective>>,
) {
    std::thread::spawn(move || {
        let n = directive_senders.len();
        let mut ap = Autopilot::new(n, AutopilotConfig::default());
        let queue_ref = ap.config().queue_ref;
        let t0 = std::time::Instant::now();
        let headroom = vec![0.0; n];
        let mut last: Vec<nestedfp::coordinator::PrecisionDirective> = Vec::new();
        loop {
            std::thread::sleep(std::time::Duration::from_millis(250));
            let outstanding = frontend.outstanding();
            let pressures: Vec<f64> =
                outstanding.iter().map(|&q| q as f64 / queue_ref).collect();
            let dirs = ap.control_at(t0.elapsed().as_secs_f64(), &pressures, 0.0, &headroom);
            // send only on change: the workers drain their (unbounded)
            // directive channels only when a job arrives, so an idle
            // fleet must not accumulate a 4 msg/s backlog forever
            if dirs != last {
                log_info!(
                    "[autopilot] severity {} directives {dirs:?} (in-flight {outstanding:?})",
                    ap.severity()
                );
                for (tx, d) in directive_senders.iter().zip(&dirs) {
                    let _ = tx.send(*d);
                }
                last = dirs;
            }
        }
    });
}

fn cmd_serve(args: &Args) -> i32 {
    let dir = artifacts_dir(args);
    let addr = args.get_or("addr", "127.0.0.1:7171").to_string();
    let policy = match args.get_or("mode", "dual") {
        "fp16" => PrecisionPolicy::Fp16Only,
        "fp8" => PrecisionPolicy::Fp8Only,
        _ => PrecisionPolicy::Dual,
    };
    let replicas = args.get_usize("replicas", 1).max(1);
    let autopilot_on = args.flag("autopilot");
    let run = || -> anyhow::Result<()> {
        // PJRT handles are not Send: each replica's runtime lives on its
        // own engine worker thread; clients talk through channels.
        let mut senders = Vec::with_capacity(replicas);
        let mut directive_senders = Vec::with_capacity(replicas);
        for replica in 0..replicas {
            let (tx, rx) = std::sync::mpsc::channel();
            let (dtx, drx) = std::sync::mpsc::channel();
            let dir2 = dir.clone();
            std::thread::spawn(move || {
                let work = || -> anyhow::Result<()> {
                    log_info!("[replica {replica}] loading artifacts from {dir2:?} ...");
                    let rt = ModelRuntime::load(
                        &dir2,
                        &["nested16", "nested8"],
                        &["decode", "prefill"],
                    )?;
                    let max_seq = rt.manifest.model.max_seq;
                    let max_batch =
                        rt.manifest.decode_buckets.iter().copied().max().unwrap_or(4);
                    let backend = RealBackend::new(
                        rt,
                        ModeMap::default(),
                        max_batch * (max_seq / 16 + 1) + 32,
                    );
                    let mut engine = Engine::new(
                        backend,
                        EngineConfig {
                            policy,
                            physical_kv: true,
                            ..Default::default()
                        },
                    );
                    log_info!("[replica {replica}] engine ready");
                    server::engine_worker_controlled(&mut engine, rx, drx)
                };
                if let Err(e) = work() {
                    log_warn!("[replica {replica}] engine worker died: {e:#}");
                }
            });
            senders.push(tx);
            directive_senders.push(dtx);
        }
        let listener = std::net::TcpListener::bind(&addr)?;
        log_info!(
            "listening on {addr} ({replicas} replica(s){}) — protocol: GEN <max_new> <prompt>",
            if autopilot_on { ", autopilot on" } else { "" }
        );
        if replicas == 1 && !autopilot_on {
            server::serve(listener, senders.pop().unwrap(), Some(b';' as i32))?;
        } else {
            let frontend = std::sync::Arc::new(server::ClusterFrontend::new(senders));
            if autopilot_on {
                spawn_autopilot_monitor(std::sync::Arc::clone(&frontend), directive_senders);
            }
            server::serve_cluster(listener, frontend, Some(b';' as i32))?;
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            log_warn!("serve: {e:#}");
            1
        }
    }
}

/// `repro analyze trace <FILE>`: validate an exported trace — parses,
/// checks span balance per (pid, tid, name, id), timestamp order — and
/// print a one-line summary. Used by the CI smoke after a `--trace` run.
fn cmd_analyze_trace(args: &Args) -> i32 {
    let Some(path) = args.positional.get(2) else {
        log_warn!("usage: repro analyze trace <FILE>");
        return 1;
    };
    let run = || -> anyhow::Result<()> {
        let text = std::fs::read_to_string(path)?;
        let chk = export::check_trace(&text)?;
        println!(
            "trace {path}: {} events ({} spans, {} instants), {} dropped — balanced",
            chk.events, chk.spans, chk.instants, chk.dropped
        );
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            log_warn!("analyze trace {path}: {e:#}");
            1
        }
    }
}

fn cmd_analyze(args: &Args) -> i32 {
    if args.positional.get(1).map(|s| s.as_str()) == Some("trace") {
        return cmd_analyze_trace(args);
    }
    let dir = artifacts_dir(args);
    let run = || -> anyhow::Result<()> {
        let ws = nestedfp::runtime::WeightStore::load(&dir.join("weights.bin"))?;
        println!(
            "weight store: {} tensors, {:.2} MiB total",
            ws.tensors.len(),
            ws.total_bytes() as f64 / (1 << 20) as f64
        );
        println!(
            "  nested planes (deployable store): {:.2} MiB == one fp16 copy",
            ws.nested_plane_bytes() as f64 / (1 << 20) as f64
        );
        println!(
            "  separate-storage co-deployment would need {:.2} MiB (+50%)",
            ws.f16_linear_bytes() as f64 * 1.5 / (1 << 20) as f64
        );
        print_reports(vec![fig3::fig3b(&dir)?, table3::table3()]);
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            log_warn!("analyze: {e:#}");
            1
        }
    }
}

fn cmd_gemm(args: &Args) -> i32 {
    let q = GemmQuery {
        m: args.get_usize("m", 128),
        n: args.get_usize("n", 4096),
        k: args.get_usize("k", 4096),
        format: match args.get_or("format", "fp16") {
            "nested16" => WeightFormat::Nested16,
            "nested8" => WeightFormat::Nested8,
            "fp8" => WeightFormat::Fp8,
            _ => WeightFormat::Fp16,
        },
        opt: OptLevel::Level3,
    };
    match gpusim::best_config(&q) {
        Some((cfg, t)) => {
            println!(
                "({}x{}x{}) {:?}: best config {} -> {:.3} ms ({:.1} TFLOP/s)",
                q.m,
                q.n,
                q.k,
                q.format,
                cfg.name(),
                t * 1e3,
                2.0 * (q.m * q.n * q.k) as f64 / t / 1e12
            );
            0
        }
        None => {
            log_warn!("no feasible kernel config");
            1
        }
    }
}

//! Custom bench harness (criterion is unavailable offline; Cargo.toml
//! sets `harness = false`).
//!
//! Benches the serving hot paths:
//!   format      — decompose / reconstruct / E4M3 throughput (bit ops)
//!   kv          — KV gather/scatter (the per-iteration memcpy cost)
//!   kvcache     — FP8 block codec encode/decode throughput
//!   scheduler   — iteration planning over a large request table
//!   gpusim      — one autotuned GEMM query (config search cost)
//!   json        — manifest parsing
//!   engine-sim  — full simulated serving iteration loop
//!   runtime     — PJRT decode step (skipped unless artifacts/ exists)
//!
//! Run: `cargo bench --offline` (add `-- <filter>` to select).

use std::time::Duration;

use nestedfp::coordinator::backend::SimBackend;
use nestedfp::coordinator::engine::{Engine, EngineConfig};
use nestedfp::coordinator::kv::{KvCacheManager, KvGeometry, KvPressureConfig};
use nestedfp::coordinator::precision::PrecisionPolicy;
use nestedfp::kvcache::codec as kv_codec;
use nestedfp::coordinator::request::{Request, RequestState};
use nestedfp::coordinator::scheduler::Scheduler;
use nestedfp::format::{e4m3, fp16::F16, nested};
use nestedfp::gpusim::{self, GemmQuery, OptLevel, WeightFormat};
use nestedfp::model::zoo;
use nestedfp::util::json::Json;
use nestedfp::util::rng::Pcg64;
use nestedfp::util::timer::{bench, fmt_ns};

fn should_run(name: &str) -> bool {
    let filters: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    filters.is_empty() || filters.iter().any(|f| name.contains(f.as_str()))
}

fn report(name: &str, per_elem: Option<(f64, &str)>, stats: nestedfp::util::timer::BenchStats) {
    print!("{name:<34} {stats}");
    if let Some((n, unit)) = per_elem {
        let rate = n / (stats.mean_ns * 1e-9);
        print!("   [{:.2} M{unit}/s]", rate / 1e6);
    }
    println!();
}

fn bench_format() {
    let mut rng = Pcg64::seeded(1);
    let weights: Vec<u16> = (0..1 << 20)
        .map(|_| F16::from_f32((rng.normal() as f32 * 0.3).clamp(-1.7, 1.7)).to_bits())
        .collect();
    let n = weights.len() as f64;

    let s = bench(3, 200, Duration::from_secs(2), || {
        let mut acc = 0u32;
        for &w in &weights {
            let (u, l) = nested::decompose(F16::from_bits(w));
            acc = acc.wrapping_add(u as u32).wrapping_add(l as u32);
        }
        std::hint::black_box(acc);
    });
    report("format/decompose 1M", Some((n, "elem")), s);

    let planes: Vec<(u8, u8)> = weights
        .iter()
        .map(|&w| nested::decompose(F16::from_bits(w)))
        .collect();
    let s = bench(3, 200, Duration::from_secs(2), || {
        let mut acc = 0u32;
        for &(u, l) in &planes {
            acc = acc.wrapping_add(nested::reconstruct(u, l).to_bits() as u32);
        }
        std::hint::black_box(acc);
    });
    report("format/reconstruct 1M", Some((n, "elem")), s);

    let floats: Vec<f32> = weights.iter().map(|&w| F16::from_bits(w).to_f32()).collect();
    let s = bench(3, 50, Duration::from_secs(2), || {
        let mut acc = 0u32;
        for &v in &floats {
            acc = acc.wrapping_add(e4m3::encode_sat(v * 256.0) as u32);
        }
        std::hint::black_box(acc);
    });
    report("format/e4m3-encode 1M", Some((n, "elem")), s);
}

fn bench_kv() {
    let geo = KvGeometry {
        n_layers: 4,
        n_heads: 8,
        max_seq: 256,
        head_dim: 32,
        block_size: 16,
        total_blocks: 4096,
    };
    let mut kv = KvCacheManager::new(geo, KvPressureConfig::dense_baseline());
    // reserve enough blocks that position 100 is table-resident
    let seqs: Vec<usize> = (0..8).map(|_| kv.allocate(112).unwrap()).collect();
    let per = geo.n_layers * geo.n_heads * geo.head_dim;
    let newk = vec![0.5f32; per];
    let newv = vec![0.25f32; per];
    let s = bench(3, 2000, Duration::from_secs(2), || {
        for &sq in &seqs {
            kv.scatter_decode(sq, 100, &newk, &newv);
        }
    });
    report("kv/scatter-decode x8", Some((8.0 * per as f64, "f32")), s);

    let mut bk = Vec::new();
    let mut bv = Vec::new();
    let s = bench(3, 500, Duration::from_secs(3), || {
        kv.gather_batch(&seqs, &mut bk, &mut bv);
        std::hint::black_box(bk.len());
    });
    report(
        "kv/gather-batch x8 (16 MiB)",
        Some((2.0 * 8.0 * geo.slot_elems() as f64, "f32")),
        s,
    );
}

fn bench_kvcache_codec() {
    // one 16-token block plane of llama-ish KV (4 layers x 8 heads x 32)
    let mut rng = Pcg64::seeded(5);
    let plane: Vec<f32> = (0..16 * 4 * 8 * 32)
        .map(|_| rng.normal() as f32)
        .collect();
    let n = plane.len() as f64;
    let s = bench(3, 2000, Duration::from_secs(2), || {
        std::hint::black_box(kv_codec::encode_block(&plane));
    });
    report("kvcache/fp8-encode block", Some((n, "elem")), s);

    let (bytes, scale) = kv_codec::encode_block(&plane);
    let mut out = vec![0.0f32; plane.len()];
    let s = bench(3, 2000, Duration::from_secs(2), || {
        kv_codec::decode_block(&bytes, scale, &mut out);
        std::hint::black_box(out[0]);
    });
    report("kvcache/fp8-decode block", Some((n, "elem")), s);
}

fn bench_scheduler() {
    let geo = KvGeometry {
        n_layers: 1,
        n_heads: 1,
        max_seq: 2048,
        head_dim: 1,
        block_size: 16,
        total_blocks: 1 << 16,
    };
    let kv = KvCacheManager::accounting_only(geo, KvPressureConfig::default());
    let mut sched = Scheduler::new(vec![64, 128, 256], 256);
    let mut requests: Vec<Request> = (0..512)
        .map(|i| {
            let mut r = Request::new(i, vec![1; 128], 128, i as f64 * 0.001);
            r.state = if i % 3 == 0 {
                RequestState::Queued
            } else {
                RequestState::Decoding
            };
            r
        })
        .collect();
    for r in requests.iter_mut() {
        if r.state == RequestState::Decoding {
            r.generated.push(1);
        }
    }
    let s = bench(10, 5000, Duration::from_secs(2), || {
        std::hint::black_box(sched.plan(&requests, &kv));
    });
    report("scheduler/plan 512 reqs", None, s);
}

fn bench_gpusim() {
    let q = GemmQuery {
        m: 256,
        n: 14336,
        k: 4096,
        format: WeightFormat::Nested16,
        opt: OptLevel::Level3,
    };
    let s = bench(3, 2000, Duration::from_secs(2), || {
        std::hint::black_box(gpusim::best_config(&q));
    });
    report("gpusim/config-search (105 cfgs)", None, s);

    let spec = zoo::find("llama31-8b").unwrap();
    let sq = gpusim::StepQuery {
        kind: gpusim::StepKind::Decode,
        m: 64,
        ctx: 512,
        seqs: 64,
        format: WeightFormat::Nested16,
        opt: OptLevel::Level3,
    };
    let s = bench(3, 5000, Duration::from_secs(2), || {
        std::hint::black_box(gpusim::step_latency(spec, &sq));
    });
    report("gpusim/step-latency (cached)", None, s);
}

fn bench_json() {
    let manifest = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = manifest {
        let bytes = text.len() as f64;
        let s = bench(3, 500, Duration::from_secs(2), || {
            std::hint::black_box(Json::parse(&text).unwrap());
        });
        report("json/parse manifest", Some((bytes, "B")), s);
    } else {
        println!("json/parse manifest               (skipped: no artifacts)");
    }
}

fn bench_engine_sim() {
    let spec = zoo::find("llama31-8b").unwrap();
    let s = bench(1, 20, Duration::from_secs(10), || {
        let backend = SimBackend::new(
            spec,
            WeightFormat::Nested16,
            WeightFormat::Nested8,
            64,
            1024,
            64 * 65 * 2,
        );
        let mut engine = Engine::new(
            backend,
            EngineConfig {
                policy: PrecisionPolicy::Dual,
                physical_kv: false,
                ..Default::default()
            },
        );
        let requests: Vec<Request> = (0..64)
            .map(|i| Request::new(i, vec![65; 128], 64, i as f64 * 0.01))
            .collect();
        std::hint::black_box(engine.run(requests).unwrap());
    });
    // 64 requests x 64 tokens = 4096 generated tokens per loop run
    report("engine-sim/64req x 64tok", Some((4096.0, "tok")), s);
}

fn bench_runtime() {
    use nestedfp::runtime::{HostTensor, ModelRuntime};
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("runtime/decode-step               (skipped: no artifacts)");
        return;
    }
    let rt = match ModelRuntime::load(dir, &["nested16"], &["decode"]) {
        Ok(rt) => rt,
        Err(e) => {
            println!("runtime/decode-step               (skipped: {e})");
            return;
        }
    };
    let (l, h, s_, dh) = (
        rt.manifest.model.n_layers,
        rt.manifest.model.n_heads,
        rt.manifest.model.max_seq,
        rt.manifest.model.head_dim,
    );
    let b = 4usize;
    let tokens = HostTensor::from_i32(vec![b], &vec![65; b]);
    let positions = HostTensor::from_i32(vec![b], &vec![0; b]);
    let kvbuf = vec![0f32; b * l * h * s_ * dh];
    let ck = HostTensor::from_f32(vec![b, l, h, s_, dh], &kvbuf);
    let cv = HostTensor::from_f32(vec![b, l, h, s_, dh], &kvbuf);
    let step = rt.step("decode", "nested16", b).unwrap();
    let stats = bench(2, 30, Duration::from_secs(15), || {
        std::hint::black_box(
            rt.run(step, &[tokens.clone(), positions.clone(), ck.clone(), cv.clone()])
                .unwrap(),
        );
    });
    report("runtime/decode-step b=4 (PJRT)", Some((b as f64, "tok")), stats);
}

fn main() {
    println!("nestedfp bench harness (std timer; criterion unavailable offline)\n");
    if should_run("format") {
        bench_format();
    }
    if should_run("kv") {
        bench_kv();
    }
    if should_run("kvcache") {
        bench_kvcache_codec();
    }
    if should_run("scheduler") {
        bench_scheduler();
    }
    if should_run("gpusim") {
        bench_gpusim();
    }
    if should_run("json") {
        bench_json();
    }
    if should_run("engine-sim") {
        bench_engine_sim();
    }
    if should_run("runtime") {
        bench_runtime();
    }
    let _ = fmt_ns(0.0);
}
